//! `sgg` — the SGG command-line launcher.
//!
//! ```text
//! sgg datasets                          list the dataset registry
//! sgg run scenario.toml [--workers N]   execute a declarative scenario spec
//!         [--resume]                    complete an interrupted shard run
//!         [--fault-seed N]              inject a transient fault schedule
//!         [--json]                      canonical-JSON report instead of prose
//! sgg test scenarios/ [--bless] [--report harness.json]
//!                                       golden-profile conformance harness
//! sgg fit --dataset ieee-fraud --out model.sggm
//! sgg generate --model model.sggm --scale 2 --out /tmp/synth [--workers N]
//! sgg fit-generate --dataset ieee-fraud --scale 2 --out /tmp/synth
//! sgg evaluate --dataset tabformer      fit + generate + Table-2 metrics
//! sgg eval --shards DIR[,DIR...] --dataset X [--json]   streamed evaluation of shard output
//! sgg plan --model model.sggm --hosts 3 --out run.json [--scale N] [--seed N]
//! sgg generate --model model.sggm --chunks A..B --manifest run.json --out-dir shard-k/
//! sgg merge --manifest run.json HOST_DIR... --out-dir merged/
//! sgg stream --nodes 1048576 --edges 50000000 --out /tmp/shards --workers 8
//!         [--format sggedge1|sggedge2]       fixed-width or varint-delta shards
//!         [--json]                      canonical-JSON stream report
//! sgg serve [--addr 127.0.0.1:7878] [--cache-dir sgg-cache]
//!         [--max-jobs N] [--queue-depth N]   HTTP generation service
//! sgg experiment table2 [--quick]       regenerate one paper table/figure
//! sgg experiment all [--quick]          regenerate everything
//! ```
//!
//! `sgg serve` exposes the scenario pipeline over HTTP (see
//! `src/serve/`): `POST /jobs` submits a scenario (TOML body) into a
//! bounded job queue (`429` + `Retry-After` when full), `GET
//! /jobs/<id>` streams the same canonical-JSON `StreamReport` lines
//! `sgg run --json` prints, `DELETE /jobs/<id>` cancels at the next
//! chunk boundary leaving a resumable shard prefix, and `POST /fit` /
//! `GET /artifacts/<hash>` fit and fetch content-addressed `.sggm`
//! model artifacts.
//!
//! `sgg eval` scores `ShardSink` output **without materializing it**:
//! shards stream chunk-by-chunk through the mergeable degree
//! accumulators (`--workers N` reads shards in parallel), and the
//! structural scores are bit-identical to the in-memory
//! `metrics::evaluate` values for any worker or shard count. The
//! reference side is `--dataset NAME` (a stand-in) or `--model m.sggm`
//! (the artifact's provenance names the dataset to reload).
//!
//! The fit/artifact/generate lifecycle: `sgg fit` learns every component
//! from a dataset and writes a versioned `.sggm` model artifact; `sgg
//! generate` loads the artifact — **no source dataset needed** — and
//! samples a synthetic dataset at any scale. For the same seed the
//! output is bit-identical to `sgg fit-generate` in one process, for any
//! `--workers` value.
//!
//! Distributed runs split one job across N shared-nothing hosts: `sgg
//! plan` writes a versioned run manifest assigning each host a chunk
//! range, each host runs `sgg generate --model m.sggm --chunks A..B
//! --manifest run.json --out-dir shard-k/`, and `sgg merge` validates
//! completeness (every chunk exactly once, checksums, model hashes),
//! assembles the canonical shard directory and folds the per-host
//! metric profiles into one quality report. The merged output is
//! byte-identical to a single-process run from the same artifact and
//! seed. Unmerged per-host output can be scored directly with `sgg eval
//! --shards dirA,dirB,...`.
//!
//! `--workers N` drives the parallel chunk runner (N sampling threads;
//! 0 = one per core). Output is bit-identical for every worker count —
//! the flag only changes wall-clock time.
//!
//! Components are selected by registry name (`--struct kronecker|
//! erdos-renyi|sbm|trilliong ...`); historical aliases (`ours`, `random`,
//! `graphworld`, `xgboost`) keep working.

use sgg::datasets::Dataset;
use sgg::pipeline::{
    self, ComponentSpec, FittedPipeline, MemorySink, Pipeline, PipelineBuilder, Registries,
    ScenarioSpec, SinkOutput, SizeSpec,
};
use sgg::structgen::chunked::ChunkConfig;
use sgg::util::args::Args;
use sgg::Result;
use std::path::Path;

fn main() {
    let args = Args::from_env();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

/// Build a pipeline from `--struct/--feat/--align/--noise/--seed` flags.
fn builder_from_args(args: &Args) -> PipelineBuilder {
    let mut builder = Pipeline::builder();
    if let Some(s) = args.get("struct") {
        let mut c = ComponentSpec::new(s);
        if let Some(noise) = args.get("noise").and_then(|v| v.parse::<f64>().ok()) {
            c = c.with("noise", noise);
        }
        if let Some(blocks) = args.get("sbm-blocks").and_then(|v| v.parse::<u64>().ok()) {
            c = c.with("blocks", blocks);
        }
        builder = builder.structure(c);
    }
    if let Some(s) = args.get("feat") {
        builder = builder.edge_features(s);
    }
    if let Some(s) = args.get("align") {
        builder = builder.aligner(s);
    }
    builder.seed(args.get_or("seed", 0x5a6e))
}

/// Shared fit phase for `fit`, `fit-generate` and `evaluate`: load the
/// `--dataset` stand-in and fit a pipeline from the component flags.
fn fit_from_args(args: &Args) -> Result<(Dataset, FittedPipeline)> {
    let name = args.get("dataset").unwrap_or("ieee-fraud");
    let ds = sgg::datasets::load(name, args.get_or("dataset-seed", 1u64))?;
    let fitted = builder_from_args(args).fit(&ds)?;
    Ok((ds, fitted))
}

/// Shared generate phase: run the fitted (or artifact-loaded) pipeline
/// through the memory sink on the parallel chunk runner. One code path
/// for every CLI entry point, so `fit`+`generate` is bit-identical to
/// `fit-generate` for the same seed at any worker count.
fn generate_dataset(fitted: &FittedPipeline, args: &Args) -> Result<Dataset> {
    let workers = match args.get_or("workers", 1usize) {
        0 => sgg::util::threadpool::default_threads(),
        w => w,
    };
    let chunks = ChunkConfig { workers, ..ChunkConfig::default() };
    let mut sink = MemorySink::new();
    fitted
        .run(
            SizeSpec::Scale(args.get_or("scale", 1u64)),
            chunks,
            &mut sink,
            args.get_or("seed", 42u64),
        )?
        .into_dataset()
}

/// Parse the optional `--format sggedge1|sggedge2` shard-encoding flag.
fn parse_format(args: &Args) -> Result<sgg::graph::io::ShardFormat> {
    match args.get("format") {
        None => Ok(sgg::graph::io::ShardFormat::default()),
        Some(name) => sgg::graph::io::ShardFormat::parse(name).ok_or_else(|| {
            sgg::Error::Config(format!(
                "unknown --format `{name}`; known: sggedge1, sggedge2"
            ))
        }),
    }
}

/// Parse a half-open `--chunks A..B` range.
fn parse_chunk_range(s: &str) -> Result<(usize, usize)> {
    let parse = |x: &str| x.trim().parse::<usize>().ok();
    let parsed = s.split_once("..").and_then(|(a, b)| Some((parse(a)?, parse(b)?)));
    match parsed {
        Some((a, b)) if a < b => Ok((a, b)),
        _ => Err(sgg::Error::Config(format!(
            "--chunks wants a non-empty half-open range like 0..6, got `{s}`"
        ))),
    }
}

/// Write the generated edge list under `--out` (if given).
fn write_edges_out(ds: &Dataset, args: &Args) -> Result<()> {
    if let Some(out) = args.get("out") {
        let dir = Path::new(out);
        std::fs::create_dir_all(dir)?;
        sgg::graph::io::write_binary(&dir.join("edges.sgg"), &ds.edges)?;
        println!("wrote {}", dir.join("edges.sgg").display());
    }
    Ok(())
}

fn run(args: &Args) -> Result<()> {
    match args.positional.first().map(|s| s.as_str()) {
        Some("datasets") => {
            for name in sgg::datasets::REGISTRY {
                let ds = sgg::datasets::load(name, 1)?;
                println!("{}", ds.summary());
            }
            Ok(())
        }
        Some("run") => {
            let path = args.positional.get(1).ok_or_else(|| {
                sgg::Error::Config(
                    "usage: sgg run <scenario.toml> [--seed N] [--workers N] [--resume] \
                     [--fault-seed N] [--fault-fatal-at CHUNK]"
                        .into(),
                )
            })?;
            let mut spec = ScenarioSpec::from_file(std::path::Path::new(path))?;
            if let Some(seed) = args.get("seed").and_then(|v| v.parse().ok()) {
                spec.seed = seed;
            }
            if let Some(workers) = args.get("workers").and_then(|v| v.parse().ok()) {
                spec.workers = workers;
                // the CLI override beats any [sink] stanza setting too
                if let sgg::pipeline::SinkSpec::Shards { chunks, .. } = &mut spec.sink {
                    chunks.workers = workers;
                }
            }
            // robustness levers: --fault-seed injects the deterministic
            // transient-fault schedule (recovered by retries, output
            // unchanged); --fault-fatal-at kills the run at a chunk so
            // `--resume` can be exercised end to end
            let mut faults = args
                .get("fault-seed")
                .and_then(|v| v.parse().ok())
                .map(sgg::pipeline::FaultPlan::transient);
            if let Some(chunk) = args.get("fault-fatal-at").and_then(|v| v.parse().ok()) {
                let mut plan =
                    faults.unwrap_or_else(|| sgg::pipeline::FaultPlan::fatal_at(chunk));
                plan.fatal_at_chunk = Some(chunk);
                faults = Some(plan);
            }
            let opts = pipeline::RunOptions {
                resume: args.has_flag("resume"),
                faults,
                ..pipeline::RunOptions::default()
            };
            let json = args.has_flag("json") || args.get("json").is_some();
            let out = pipeline::run_scenario_opts(&spec, &Registries::builtin(), opts)?;
            // the shard path carries its tapped quality inside the
            // stream report; the memory path scores the full Table-2
            // metrics here
            let quality = match (&out, spec.evaluate) {
                (SinkOutput::Dataset(synth), true) => {
                    let ds = sgg::datasets::load(&spec.dataset, spec.dataset_seed)?;
                    Some(
                        sgg::metrics::Evaluator::new(&ds.edges, &ds.edge_features)
                            .score(&synth.edges, &synth.edge_features),
                    )
                }
                _ => None,
            };
            if json {
                // one canonical-JSON line; the shard-run form is the
                // exact serialization `GET /jobs/<id>` streams
                match &out {
                    SinkOutput::Streamed(report) => println!("{}", report.to_json()),
                    SinkOutput::Dataset(synth) => {
                        let quality_json = quality
                            .as_ref()
                            .map(|q| q.to_json())
                            .unwrap_or(sgg::util::json::Json::Null);
                        println!(
                            "{}",
                            sgg::util::json::Json::obj(vec![
                                ("edge_feature_cols", synth.edge_features.n_cols().into()),
                                (
                                    "edges",
                                    sgg::util::json::Json::u64_exact(synth.edges.len() as u64)
                                ),
                                (
                                    "nodes",
                                    sgg::util::json::Json::u64_exact(
                                        synth.edges.n_nodes() as u64
                                    )
                                ),
                                ("quality", quality_json),
                                ("scenario", spec.name.as_str().into()),
                            ])
                        );
                    }
                }
            } else {
                println!("scenario `{}`: {}", spec.name, out.summary());
                if let Some(report) = &quality {
                    println!("quality[{}]: {report}", spec.name);
                }
            }
            if let (SinkOutput::Dataset(ds), Some(dir)) = (&out, args.get("out")) {
                let dir = std::path::Path::new(dir);
                std::fs::create_dir_all(dir)?;
                sgg::graph::io::write_binary(&dir.join("edges.sgg"), &ds.edges)?;
                println!("wrote {}", dir.join("edges.sgg").display());
            }
            Ok(())
        }
        Some("fit") => {
            let out = args.get("out").unwrap_or("model.sggm");
            let (ds, fitted) = fit_from_args(args)?;
            fitted.save(Path::new(out))?;
            let bytes = std::fs::metadata(out).map(|m| m.len()).unwrap_or(0);
            let (s, f, a) = fitted.component_names();
            println!(
                "fitted `{}` (structure={s}, features={f}, aligner={a}) → {out} ({bytes} bytes)",
                ds.name
            );
            Ok(())
        }
        Some("generate") => {
            let model = args.get("model").ok_or_else(|| {
                sgg::Error::Config(
                    "usage: sgg generate --model model.sggm [--scale N] [--seed N] \
                     [--workers N] [--out dir]"
                        .into(),
                )
            })?;
            for flag in ["struct", "feat", "align", "dataset", "noise", "sbm-blocks"] {
                if args.get(flag).is_some() {
                    return Err(sgg::Error::Config(format!(
                        "--{flag} has no effect with --model: the artifact already carries \
                         the fitted components (use `sgg fit` to change them)"
                    )));
                }
            }
            if let Some(range) = args.get("chunks") {
                // one host's slice of a planned distributed run: the
                // manifest fixes the job, the range picks this host's part
                let usage = "usage: sgg generate --model m.sggm --chunks A..B \
                             --manifest run.json --out-dir DIR [--workers N] [--resume] \
                             [--format sggedge1|sggedge2]";
                for flag in ["scale", "seed", "out"] {
                    if args.get(flag).is_some() {
                        return Err(sgg::Error::Config(format!(
                            "--{flag} has no effect with --chunks: the run manifest fixes \
                             the job (re-run `sgg plan` to change it)"
                        )));
                    }
                }
                let manifest_path = args
                    .get("manifest")
                    .ok_or_else(|| sgg::Error::Config(usage.into()))?;
                let out_dir = args
                    .get("out-dir")
                    .ok_or_else(|| sgg::Error::Config(usage.into()))?;
                let manifest = pipeline::distrib::RunManifest::load(Path::new(manifest_path))?;
                let (start, end) = parse_chunk_range(range)?;
                let workers = match args.get_or("workers", 1usize) {
                    0 => sgg::util::threadpool::default_threads(),
                    w => w,
                };
                let (host, stream) = pipeline::distrib::run_host_range(
                    Path::new(model),
                    &manifest,
                    start,
                    end,
                    Path::new(out_dir),
                    workers,
                    args.has_flag("resume"),
                    parse_format(args)?,
                    &Registries::builtin(),
                )?;
                println!(
                    "host chunks {start}..{end} of {}: {stream}; {} shard records → {}/{}",
                    manifest.total_chunks,
                    host.chunks.len(),
                    out_dir,
                    pipeline::distrib::HOST_REPORT_FILE
                );
                return Ok(());
            }
            let fitted = FittedPipeline::load(Path::new(model), &Registries::builtin())?;
            let src = fitted.source();
            println!(
                "loaded `{}` (fitted on `{}`: {} edges over {}×{})",
                model, src.dataset, src.edges, src.spec.n_src, src.spec.n_dst
            );
            let synth = generate_dataset(&fitted, args)?;
            println!(
                "generated `{}`: {} nodes, {} edges, {} feature cols",
                synth.name,
                synth.edges.n_nodes(),
                synth.edges.len(),
                synth.edge_features.n_cols()
            );
            write_edges_out(&synth, args)?;
            Ok(())
        }
        Some("plan") => {
            let usage = "usage: sgg plan --model m.sggm --hosts N --out run.json \
                         [--scale N] [--seed N] [--prefix-levels L]";
            let model = args.get("model").ok_or_else(|| sgg::Error::Config(usage.into()))?;
            let hosts = args.get_or("hosts", 0usize);
            if hosts == 0 {
                return Err(sgg::Error::Config(usage.into()));
            }
            let out = args.get("out").unwrap_or("run.json");
            let defaults = ChunkConfig::default();
            let manifest = pipeline::distrib::plan_run(
                Path::new(model),
                hosts,
                args.get_or("scale", 1u64),
                args.get_or("seed", 42u64),
                args.get_or("prefix-levels", defaults.prefix_levels),
                &Registries::builtin(),
            )?;
            manifest.save(Path::new(out))?;
            println!(
                "planned {} chunks ({} edges over {}×{}) across {hosts} hosts → {out}",
                manifest.total_chunks, manifest.edges, manifest.n_src, manifest.n_dst
            );
            for h in &manifest.hosts {
                println!(
                    "  host {}: sgg generate --model {model} --chunks {}..{} \
                     --manifest {out} --out-dir shard-{}/",
                    h.host, h.start, h.end, h.host
                );
            }
            Ok(())
        }
        Some("merge") => {
            let usage = "usage: sgg merge --manifest run.json HOST_DIR... --out-dir merged/ \
                         [--dataset-seed N] [--workers N]";
            let manifest_path = args
                .get("manifest")
                .ok_or_else(|| sgg::Error::Config(usage.into()))?;
            let out_dir = args
                .get("out-dir")
                .ok_or_else(|| sgg::Error::Config(usage.into()))?;
            let dirs: Vec<std::path::PathBuf> =
                args.positional[1..].iter().map(std::path::PathBuf::from).collect();
            if dirs.is_empty() {
                return Err(sgg::Error::Config(usage.into()));
            }
            let manifest = pipeline::distrib::RunManifest::load(Path::new(manifest_path))?;
            // the manifest's provenance names the quality reference, as
            // with `sgg eval --model`
            let reference =
                sgg::datasets::load(&manifest.dataset, args.get_or("dataset-seed", 1u64))?;
            let orig = sgg::metrics::DegreeProfile::of(&reference.edges);
            // `--workers 0` = one per core, as elsewhere; the default of
            // 1 keeps the historical single-threaded verify behavior
            let workers = match args.get_or("workers", 1usize) {
                0 => sgg::util::threadpool::default_threads(),
                w => w,
            };
            let report = pipeline::distrib::merge_run_with(
                &manifest,
                &dirs,
                Path::new(out_dir),
                Some(&orig),
                workers,
            )?;
            println!("{report}");
            Ok(())
        }
        Some("fit-generate") => {
            let (_ds, fitted) = fit_from_args(args)?;
            let synth = generate_dataset(&fitted, args)?;
            println!(
                "generated `{}`: {} nodes, {} edges, {} feature cols",
                synth.name,
                synth.edges.n_nodes(),
                synth.edges.len(),
                synth.edge_features.n_cols()
            );
            write_edges_out(&synth, args)?;
            Ok(())
        }
        Some("evaluate") => {
            let (ds, fitted) = fit_from_args(args)?;
            let synth = generate_dataset(&fitted, args)?;
            let report = sgg::metrics::Evaluator::new(&ds.edges, &ds.edge_features)
                .score(&synth.edges, &synth.edge_features);
            println!("{}: {report}", ds.name);
            Ok(())
        }
        Some("eval") => {
            let usage = "usage: sgg eval --shards DIR[,DIR...] (--dataset NAME | \
                         --model m.sggm) [--dataset-seed N] [--workers N] [--json]";
            let json = args.has_flag("json") || args.get("json").is_some();
            let shards = args
                .get("shards")
                .ok_or_else(|| sgg::Error::Config(usage.into()))?;
            let workers = match args.get_or("workers", 1usize) {
                0 => sgg::util::threadpool::default_threads(),
                w => w,
            };
            let reference = match (args.get("model"), args.get("dataset")) {
                (Some(_), Some(_)) => {
                    return Err(sgg::Error::Config(
                        "give either --dataset or --model as the eval reference, not both"
                            .into(),
                    ));
                }
                (Some(model), None) => {
                    // the artifact's provenance header names the fit
                    // dataset — no component is deserialized
                    let src = FittedPipeline::read_provenance(Path::new(model))?;
                    if !json {
                        println!("reference from `{model}`: dataset `{}`", src.dataset);
                    }
                    sgg::datasets::load(&src.dataset, args.get_or("dataset-seed", 1u64))?
                }
                (None, Some(name)) => {
                    sgg::datasets::load(name, args.get_or("dataset-seed", 1u64))?
                }
                (None, None) => return Err(sgg::Error::Config(usage.into())),
            };
            let orig = sgg::metrics::DegreeProfile::of(&reference.edges);
            // comma-separated directories score the unmerged per-host
            // output of a distributed run as one logical graph
            let dirs: Vec<std::path::PathBuf> = shards
                .split(',')
                .filter(|s| !s.is_empty())
                .map(std::path::PathBuf::from)
                .collect();
            let report = sgg::metrics::stream::evaluate_shard_dirs(&dirs, &orig, workers)?;
            if json {
                println!("{}", report.to_json());
            } else {
                println!("{} vs {}: {report}", shards, reference.name);
            }
            Ok(())
        }
        Some("stream") => {
            let nodes = args.get_or("nodes", 1u64 << 20);
            let edges = args.get_or("edges", 10_000_000u64);
            let out = args.get("out").unwrap_or("/tmp/sgg-shards").to_string();
            let gen = sgg::structgen::kronecker::KroneckerGen::new(
                sgg::structgen::theta::ThetaS::rmat_default(),
                sgg::graph::PartiteSpec::square(nodes),
                edges,
            );
            let defaults = sgg::structgen::chunked::ChunkConfig::default();
            let workers = match args.get_or("workers", defaults.workers) {
                0 => sgg::util::threadpool::default_threads(),
                w => w,
            };
            let cfg = sgg::structgen::chunked::ChunkConfig {
                prefix_levels: args.get_or("prefix-levels", defaults.prefix_levels),
                workers,
                queue_capacity: args.get_or("queue-capacity", defaults.queue_capacity),
                format: parse_format(args)?,
                ..defaults
            };
            let report = sgg::pipeline::orchestrator::stream_to_shards_opts(
                &gen,
                nodes,
                nodes,
                edges,
                args.get_or("seed", 7u64),
                cfg,
                std::path::Path::new(&out),
                args.has_flag("resume"),
            )?;
            if args.has_flag("json") || args.get("json").is_some() {
                println!("{}", report.to_json());
            } else {
                println!("{report}");
            }
            Ok(())
        }
        Some("test") => {
            let dir = args
                .positional
                .get(1)
                .map(|s| s.as_str())
                .unwrap_or("scenarios");
            let mut cfg = sgg::harness::HarnessConfig::new(Path::new(dir));
            cfg.bless = args.has_flag("bless");
            cfg.workers = args.get_or("workers", cfg.workers);
            cfg.fault_seed = args.get_or("fault-seed", cfg.fault_seed);
            if let Some(w) = args.get("workdir") {
                cfg.workdir = std::path::PathBuf::from(w);
            }
            let report = sgg::harness::run_harness(&cfg)?;
            for s in &report.scenarios {
                match &s.status {
                    sgg::harness::ScenarioStatus::Passed => {
                        let p = s.profile.expect("passed implies profile");
                        println!(
                            "PASS  {}: {} edges in {} shards, degree_dist={:.4} dcc={:.4} \
                             (fault re-run identical)",
                            s.name, p.edges, p.shards, p.degree_dist, p.dcc
                        );
                    }
                    sgg::harness::ScenarioStatus::Blessed => {
                        let p = s.profile.expect("blessed implies profile");
                        println!(
                            "BLESS {}: golden pinned at {} edges in {} shards, \
                             degree_dist={:.4} dcc={:.4}",
                            s.name, p.edges, p.shards, p.degree_dist, p.dcc
                        );
                    }
                    sgg::harness::ScenarioStatus::Failed(why) => {
                        println!("FAIL  {}: {why}", s.name);
                    }
                }
            }
            if let Some(path) = args.get("report") {
                sgg::harness::write_report(Path::new(path), &report)?;
                println!("report → {path}");
            }
            if report.passed() {
                Ok(())
            } else {
                let failed = report
                    .scenarios
                    .iter()
                    .filter(|s| matches!(s.status, sgg::harness::ScenarioStatus::Failed(_)))
                    .count();
                Err(sgg::Error::Data(format!(
                    "{failed} of {} scenarios failed conformance",
                    report.scenarios.len()
                )))
            }
        }
        Some("serve") => {
            let addr = args.get("addr").unwrap_or("127.0.0.1:7878").to_string();
            let cache_dir = args.get("cache-dir").unwrap_or("sgg-cache").to_string();
            // --max-jobs 0 means "one per core" on the CLI; the paused
            // workers=0 mode is a library-level test knob only
            let workers = match args.get_or("max-jobs", 0usize) {
                0 => sgg::util::threadpool::default_threads(),
                w => w,
            };
            let queue_depth = args.get_or("queue-depth", 8usize);
            let server = sgg::serve::Server::bind(&sgg::serve::ServeConfig {
                addr,
                cache_dir: std::path::PathBuf::from(&cache_dir),
                workers,
                queue_depth,
            })?;
            println!(
                "sgg serve listening on {} ({workers} job workers, queue depth \
                 {queue_depth}, cache {cache_dir})",
                server.local_addr()?
            );
            server.run()
        }
        Some("experiment") => {
            let quick = args.has_flag("quick") || args.get("quick").is_some();
            let id = args
                .positional
                .get(1)
                .cloned()
                .unwrap_or_else(|| "all".to_string());
            if id == "all" {
                for id in sgg::experiments::ALL {
                    sgg::experiments::run(id, quick)?;
                }
            } else {
                sgg::experiments::run(&id, quick)?;
            }
            Ok(())
        }
        _ => {
            println!(
                "usage: sgg <datasets|run|test|fit|generate|plan|merge|fit-generate|evaluate|eval|stream|serve|experiment> [--options]\n\
                 lifecycle: sgg fit --dataset ieee-fraud --out m.sggm && \
                 sgg generate --model m.sggm --scale 2 --out /tmp/synth\n\
                 distributed: sgg plan --model m.sggm --hosts 3 --out run.json; \
                 sgg generate --model m.sggm --chunks A..B --manifest run.json --out-dir shard-k/; \
                 sgg merge --manifest run.json shard-*/ --out-dir merged/\n\
                 streamed eval: sgg eval --shards /tmp/shards --dataset ieee-fraud --workers 4 \
                 (comma-separate unmerged host dirs)\n\
                 service: sgg serve --addr 127.0.0.1:7878 --cache-dir sgg-cache \
                 (POST /jobs, GET /jobs/<id>, POST /fit, GET /artifacts/<hash>)\n\
                 conformance: sgg test scenarios/ [--bless] [--report harness.json]\n\
                 recovery: sgg run scenarios/fraud.toml --resume (after an interrupted shard run)\n\
                 experiments: {:?}\n\
                 components: --struct kronecker|kronecker-noisy|erdos-renyi|sbm|trilliong  \
                 --feat gan|kde|random|gaussian  --align learned|random\n\
                 parallelism: --workers N (run/generate/fit-generate/eval/stream; 0 = one per core)\n\
                 spec files: sgg run scenarios/fraud.toml (see docs/scenario-reference.md)",
                sgg::experiments::ALL
            );
            Ok(())
        }
    }
}
