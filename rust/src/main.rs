//! `sgg` — the SGG command-line launcher.
//!
//! ```text
//! sgg datasets                          list the dataset registry
//! sgg fit-generate --dataset ieee-fraud --scale 2 --out /tmp/synth
//! sgg evaluate --dataset tabformer      fit + generate + Table-2 metrics
//! sgg stream --nodes 1048576 --edges 50000000 --out /tmp/shards
//! sgg experiment table2 [--quick]       regenerate one paper table/figure
//! sgg experiment all [--quick]          regenerate everything
//! ```

use sgg::pipeline::{Pipeline, PipelineConfig};
use sgg::util::args::Args;
use sgg::Result;

fn main() {
    let args = Args::from_env();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn pipeline_config(args: &Args) -> Result<PipelineConfig> {
    let mut cfg = PipelineConfig::default();
    if let Some(s) = args.get("struct") {
        cfg.struct_kind = s.parse().map_err(sgg::Error::Config)?;
    }
    if let Some(s) = args.get("feat") {
        cfg.feat_kind = s.parse().map_err(sgg::Error::Config)?;
    }
    if let Some(s) = args.get("align") {
        cfg.align_kind = s.parse().map_err(sgg::Error::Config)?;
    }
    cfg.noise = args.get_or("noise", cfg.noise);
    cfg.seed = args.get_or("seed", cfg.seed);
    Ok(cfg)
}

fn run(args: &Args) -> Result<()> {
    match args.positional.first().map(|s| s.as_str()) {
        Some("datasets") => {
            for name in sgg::datasets::REGISTRY {
                let ds = sgg::datasets::load(name, 1)?;
                println!("{}", ds.summary());
            }
            Ok(())
        }
        Some("fit-generate") => {
            let name = args.get("dataset").unwrap_or("ieee-fraud");
            let scale = args.get_or("scale", 1u64);
            let seed = args.get_or("seed", 42u64);
            let ds = sgg::datasets::load(name, 1)?;
            let cfg = pipeline_config(args)?;
            let fitted = Pipeline::fit(&ds, &cfg)?;
            let synth = fitted.generate(scale, seed)?;
            println!(
                "generated `{}`: {} nodes, {} edges, {} feature cols",
                synth.name,
                synth.edges.n_nodes(),
                synth.edges.len(),
                synth.edge_features.n_cols()
            );
            if let Some(out) = args.get("out") {
                let dir = std::path::Path::new(out);
                std::fs::create_dir_all(dir)?;
                sgg::graph::io::write_binary(&dir.join("edges.sgg"), &synth.edges)?;
                println!("wrote {}", dir.join("edges.sgg").display());
            }
            Ok(())
        }
        Some("evaluate") => {
            let name = args.get("dataset").unwrap_or("ieee-fraud");
            let ds = sgg::datasets::load(name, 1)?;
            let cfg = pipeline_config(args)?;
            let fitted = Pipeline::fit(&ds, &cfg)?;
            let synth = fitted.generate(args.get_or("scale", 1u64), args.get_or("seed", 42u64))?;
            let report = sgg::metrics::evaluate(
                &ds.edges,
                &ds.edge_features,
                &synth.edges,
                &synth.edge_features,
            );
            println!("{name}: {report}");
            Ok(())
        }
        Some("stream") => {
            let nodes = args.get_or("nodes", 1u64 << 20);
            let edges = args.get_or("edges", 10_000_000u64);
            let out = args.get("out").unwrap_or("/tmp/sgg-shards").to_string();
            let gen = sgg::structgen::kronecker::KroneckerGen::new(
                sgg::structgen::theta::ThetaS::rmat_default(),
                sgg::graph::PartiteSpec::square(nodes),
                edges,
            );
            let report = sgg::pipeline::orchestrator::stream_to_shards(
                &gen,
                nodes,
                nodes,
                edges,
                args.get_or("seed", 7u64),
                sgg::structgen::chunked::ChunkConfig::default(),
                std::path::Path::new(&out),
            )?;
            println!("{report}");
            Ok(())
        }
        Some("experiment") => {
            let quick = args.has_flag("quick") || args.get("quick").is_some();
            let id = args
                .positional
                .get(1)
                .cloned()
                .unwrap_or_else(|| "all".to_string());
            if id == "all" {
                for id in sgg::experiments::ALL {
                    sgg::experiments::run(id, quick)?;
                }
            } else {
                sgg::experiments::run(&id, quick)?;
            }
            Ok(())
        }
        _ => {
            println!(
                "usage: sgg <datasets|fit-generate|evaluate|stream|experiment> [--options]\n\
                 experiments: {:?}\n\
                 components: --struct kronecker|random|sbm|trilliong  \
                 --feat gan|kde|random|gaussian  --align xgboost|random",
                sgg::experiments::ALL
            );
            Ok(())
        }
    }
}
