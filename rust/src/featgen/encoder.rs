//! Mode-specific normalization encoder (paper §3.3, after Xu et al. [44]).
//!
//! Continuous columns are encoded as `(α, one-hot mode)` pairs where the
//! mode is the most-responsible component of a per-column Gaussian
//! mixture ([`crate::featgen::gmm`]) and α the in-mode normalized scalar;
//! categorical columns as one-hot vectors. The resulting dense f32 matrix
//! is what the GAN trains on; [`ModeSpecificEncoder::decode`] inverts the
//! transform on generated rows.

use super::gmm::Gmm;
use super::table::{Column, ColumnData, FeatureTable};
use crate::error::{Error, Result};
use crate::util::json::Json;

/// Per-column encoding metadata.
#[derive(Clone, Debug)]
enum ColCodec {
    /// Continuous: α scalar followed by `gmm.n_components()` mode slots.
    Continuous { name: String, gmm: Gmm },
    /// Categorical: `cardinality` one-hot slots.
    Categorical { name: String, cardinality: u32 },
}

/// Fitted encoder mapping a [`FeatureTable`] to a dense f32 matrix.
#[derive(Clone, Debug)]
pub struct ModeSpecificEncoder {
    codecs: Vec<ColCodec>,
    width: usize,
}

/// Maximum GMM components per continuous column (CTGAN uses 10).
pub const MAX_MODES: usize = 8;

impl ModeSpecificEncoder {
    /// Fit the per-column codecs.
    pub fn fit(table: &FeatureTable) -> ModeSpecificEncoder {
        let mut codecs = Vec::with_capacity(table.n_cols());
        let mut width = 0usize;
        for (i, c) in table.columns.iter().enumerate() {
            match &c.data {
                ColumnData::Continuous(v) => {
                    let gmm = Gmm::fit(v, MAX_MODES, 20, 0.02, 0x5eed ^ i as u64);
                    width += 1 + gmm.n_components();
                    codecs.push(ColCodec::Continuous { name: c.name.clone(), gmm });
                }
                ColumnData::Categorical { cardinality, .. } => {
                    width += (*cardinality).max(1) as usize;
                    codecs.push(ColCodec::Categorical {
                        name: c.name.clone(),
                        cardinality: (*cardinality).max(1),
                    });
                }
            }
        }
        ModeSpecificEncoder { codecs, width }
    }

    /// Encoded row width.
    pub fn width(&self) -> usize {
        self.width
    }

    /// Serialize the fitted codecs for a `.sggm` model artifact.
    pub fn to_json(&self) -> Json {
        let codecs = self
            .codecs
            .iter()
            .map(|c| match c {
                ColCodec::Continuous { name, gmm } => Json::obj(vec![
                    ("name", Json::from(name.as_str())),
                    ("kind", Json::from("continuous")),
                    ("gmm", gmm.to_json()),
                ]),
                ColCodec::Categorical { name, cardinality } => Json::obj(vec![
                    ("name", Json::from(name.as_str())),
                    ("kind", Json::from("categorical")),
                    ("cardinality", Json::from(*cardinality)),
                ]),
            })
            .collect();
        Json::obj(vec![("codecs", Json::Arr(codecs))])
    }

    /// Inverse of [`ModeSpecificEncoder::to_json`]; the encoded width is
    /// re-derived from the codecs.
    pub fn from_json(v: &Json) -> Result<ModeSpecificEncoder> {
        let mut codecs = Vec::new();
        let mut width = 0usize;
        for c in v.req_arr("codecs")? {
            let name = c.req_str("name")?.to_string();
            match c.req_str("kind")? {
                "continuous" => {
                    let gmm = Gmm::from_json(c.req("gmm")?)?;
                    width += 1 + gmm.n_components();
                    codecs.push(ColCodec::Continuous { name, gmm });
                }
                "categorical" => {
                    let cardinality = c.req_u32("cardinality")?;
                    width += cardinality.max(1) as usize;
                    codecs.push(ColCodec::Categorical { name, cardinality });
                }
                other => {
                    return Err(Error::Data(format!(
                        "artifact: unknown encoder codec kind `{other}`"
                    )))
                }
            }
        }
        Ok(ModeSpecificEncoder { codecs, width })
    }

    /// Encode the table into a row-major f32 matrix `n_rows × width`.
    pub fn encode(&self, table: &FeatureTable) -> Result<Vec<f32>> {
        let n = table.n_rows();
        if table.n_cols() != self.codecs.len() {
            return Err(Error::Data("encoder/table column mismatch".into()));
        }
        let mut out = vec![0.0f32; n * self.width];
        for r in 0..n {
            let mut off = r * self.width;
            for (ci, codec) in self.codecs.iter().enumerate() {
                match (codec, &table.columns[ci].data) {
                    (ColCodec::Continuous { gmm, .. }, ColumnData::Continuous(v)) => {
                        let (mode, alpha) = gmm.encode(v[r]);
                        out[off] = alpha as f32;
                        out[off + 1 + mode] = 1.0;
                        off += 1 + gmm.n_components();
                    }
                    (ColCodec::Categorical { cardinality, .. }, ColumnData::Categorical { codes, .. }) => {
                        out[off + codes[r] as usize] = 1.0;
                        off += *cardinality as usize;
                    }
                    _ => return Err(Error::Data("column type mismatch vs encoder".into())),
                }
            }
        }
        Ok(out)
    }

    /// Decode a row-major f32 matrix back into a [`FeatureTable`]. Mode /
    /// category slots are resolved by argmax (generated outputs are soft).
    pub fn decode(&self, data: &[f32], n_rows: usize) -> Result<FeatureTable> {
        if data.len() != n_rows * self.width {
            return Err(Error::Data(format!(
                "decode: got {} values, want {}",
                data.len(),
                n_rows * self.width
            )));
        }
        let mut columns: Vec<Column> = Vec::with_capacity(self.codecs.len());
        // column-major accumulation
        let mut cont_vals: Vec<Vec<f64>> = Vec::new();
        let mut cat_vals: Vec<Vec<u32>> = Vec::new();
        for codec in &self.codecs {
            match codec {
                ColCodec::Continuous { .. } => cont_vals.push(Vec::with_capacity(n_rows)),
                ColCodec::Categorical { .. } => cat_vals.push(Vec::with_capacity(n_rows)),
            }
        }
        for r in 0..n_rows {
            let mut off = r * self.width;
            let mut ic = 0;
            let mut ik = 0;
            for codec in &self.codecs {
                match codec {
                    ColCodec::Continuous { gmm, .. } => {
                        let k = gmm.n_components();
                        let alpha = data[off] as f64;
                        let mode = argmax(&data[off + 1..off + 1 + k]);
                        cont_vals[ic].push(gmm.decode(mode, alpha));
                        ic += 1;
                        off += 1 + k;
                    }
                    ColCodec::Categorical { cardinality, .. } => {
                        let k = *cardinality as usize;
                        cat_vals[ik].push(argmax(&data[off..off + k]) as u32);
                        ik += 1;
                        off += k;
                    }
                }
            }
        }
        let mut ic = 0;
        let mut ik = 0;
        for codec in &self.codecs {
            match codec {
                ColCodec::Continuous { name, .. } => {
                    columns.push(Column {
                        name: name.clone(),
                        data: ColumnData::Continuous(std::mem::take(&mut cont_vals[ic])),
                    });
                    ic += 1;
                }
                ColCodec::Categorical { name, cardinality } => {
                    columns.push(Column {
                        name: name.clone(),
                        data: ColumnData::Categorical {
                            codes: std::mem::take(&mut cat_vals[ik]),
                            cardinality: *cardinality,
                        },
                    });
                    ik += 1;
                }
            }
        }
        FeatureTable::new(columns)
    }

    /// Paper §12's embedding-size rule for categorical columns:
    /// `min(600, round(1.6·|D|^0.56))` — exposed for the L2 model config.
    pub fn embedding_dim(cardinality: u32) -> usize {
        (1.6 * (cardinality as f64).powf(0.56)).round().min(600.0) as usize
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > best_v {
            best_v = x;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn table() -> FeatureTable {
        let mut rng = Pcg64::new(11);
        let vals: Vec<f64> = (0..800)
            .map(|i| if i % 2 == 0 { rng.normal_ms(-4.0, 0.3) } else { rng.normal_ms(6.0, 0.5) })
            .collect();
        let codes: Vec<u32> = (0..800).map(|i| (i % 5) as u32).collect();
        FeatureTable::new(vec![
            Column::continuous("v", vals),
            Column::categorical("c", codes),
        ])
        .unwrap()
    }

    #[test]
    fn width_accounts_for_modes_and_onehot() {
        let t = table();
        let enc = ModeSpecificEncoder::fit(&t);
        // v: 1 + n_modes; c: 5
        let v_modes = enc.width() - 5 - 1;
        assert!(v_modes >= 2, "modes={v_modes}");
    }

    #[test]
    fn encode_decode_roundtrip() {
        let t = table();
        let enc = ModeSpecificEncoder::fit(&t);
        let data = enc.encode(&t).unwrap();
        let back = enc.decode(&data, t.n_rows()).unwrap();
        // categorical: exact roundtrip
        assert_eq!(
            back.column("c").unwrap().as_categorical().0,
            t.column("c").unwrap().as_categorical().0
        );
        // continuous: within in-mode error
        let orig = t.column("v").unwrap().as_continuous();
        let rec = back.column("v").unwrap().as_continuous();
        for (a, b) in orig.iter().zip(rec).take(200) {
            assert!((a - b).abs() < 0.8, "{a} vs {b}");
        }
    }

    #[test]
    fn onehot_rows_are_valid() {
        let t = table();
        let enc = ModeSpecificEncoder::fit(&t);
        let data = enc.encode(&t).unwrap();
        let w = enc.width();
        // each row: exactly 1 one-hot among last 5 slots
        for r in 0..10 {
            let row = &data[r * w..(r + 1) * w];
            let cat_ones: f32 = row[w - 5..].iter().sum();
            assert_eq!(cat_ones, 1.0);
        }
    }

    #[test]
    fn embedding_dim_rule() {
        assert_eq!(ModeSpecificEncoder::embedding_dim(2), 2);
        assert!(ModeSpecificEncoder::embedding_dim(100_000) <= 600);
        // paper formula: 1.6 * 50^0.56 ≈ 14.3
        assert_eq!(ModeSpecificEncoder::embedding_dim(50), 14);
    }

    #[test]
    fn decode_rejects_bad_shape() {
        let t = table();
        let enc = ModeSpecificEncoder::fit(&t);
        assert!(enc.decode(&[0.0; 7], 3).is_err());
    }
}
