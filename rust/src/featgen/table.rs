//! Tabular feature container: the `F_V` / `F_E` matrices of the paper,
//! stored column-major with explicit continuous/categorical typing
//! (the multi-modal setting of §3.3).

use crate::error::{Error, Result};
use crate::util::json::Json;

/// Column payload.
#[derive(Clone, Debug, PartialEq)]
pub enum ColumnData {
    /// Continuous feature values.
    Continuous(Vec<f64>),
    /// Categorical codes in [0, cardinality).
    Categorical { codes: Vec<u32>, cardinality: u32 },
}

/// A named, typed feature column.
#[derive(Clone, Debug, PartialEq)]
pub struct Column {
    /// Column name (unique within a table).
    pub name: String,
    /// Typed values.
    pub data: ColumnData,
}

impl Column {
    /// New continuous column.
    pub fn continuous(name: &str, values: Vec<f64>) -> Column {
        Column { name: name.to_string(), data: ColumnData::Continuous(values) }
    }

    /// New categorical column; cardinality inferred from the codes.
    pub fn categorical(name: &str, codes: Vec<u32>) -> Column {
        let cardinality = codes.iter().copied().max().map(|m| m + 1).unwrap_or(0);
        Column { name: name.to_string(), data: ColumnData::Categorical { codes, cardinality } }
    }

    /// Number of rows.
    pub fn len(&self) -> usize {
        match &self.data {
            ColumnData::Continuous(v) => v.len(),
            ColumnData::Categorical { codes, .. } => codes.len(),
        }
    }

    /// True when the column has no rows.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// True for continuous columns.
    pub fn is_continuous(&self) -> bool {
        matches!(self.data, ColumnData::Continuous(_))
    }

    /// Continuous values (panics on categorical — use after checking).
    pub fn as_continuous(&self) -> &[f64] {
        match &self.data {
            ColumnData::Continuous(v) => v,
            _ => panic!("column `{}` is not continuous", self.name),
        }
    }

    /// Categorical codes.
    pub fn as_categorical(&self) -> (&[u32], u32) {
        match &self.data {
            ColumnData::Categorical { codes, cardinality } => (codes, *cardinality),
            _ => panic!("column `{}` is not categorical", self.name),
        }
    }
}

/// A table of equally long feature columns.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FeatureTable {
    /// Columns, all of equal length.
    pub columns: Vec<Column>,
}

impl FeatureTable {
    /// Build, validating equal column lengths.
    pub fn new(columns: Vec<Column>) -> Result<FeatureTable> {
        if let Some(first) = columns.first() {
            let n = first.len();
            for c in &columns {
                if c.len() != n {
                    return Err(Error::Data(format!(
                        "column `{}` has {} rows, expected {n}",
                        c.name,
                        c.len()
                    )));
                }
            }
        }
        Ok(FeatureTable { columns })
    }

    /// Number of rows.
    pub fn n_rows(&self) -> usize {
        self.columns.first().map(|c| c.len()).unwrap_or(0)
    }

    /// Number of columns.
    pub fn n_cols(&self) -> usize {
        self.columns.len()
    }

    /// Indices of continuous / categorical columns.
    pub fn split_indices(&self) -> (Vec<usize>, Vec<usize>) {
        let mut cont = Vec::new();
        let mut cat = Vec::new();
        for (i, c) in self.columns.iter().enumerate() {
            if c.is_continuous() {
                cont.push(i);
            } else {
                cat.push(i);
            }
        }
        (cont, cat)
    }

    /// Extract row `i` as (continuous values, categorical codes) in
    /// column order.
    pub fn row(&self, i: usize) -> (Vec<f64>, Vec<u32>) {
        let mut cont = Vec::new();
        let mut cat = Vec::new();
        for c in &self.columns {
            match &c.data {
                ColumnData::Continuous(v) => cont.push(v[i]),
                ColumnData::Categorical { codes, .. } => cat.push(codes[i]),
            }
        }
        (cont, cat)
    }

    /// Gather a subset of rows into a new table (row `perm[i]` of self
    /// becomes row i). Indices may repeat.
    pub fn gather(&self, perm: &[usize]) -> FeatureTable {
        let columns = self
            .columns
            .iter()
            .map(|c| Column {
                name: c.name.clone(),
                data: match &c.data {
                    ColumnData::Continuous(v) => {
                        ColumnData::Continuous(perm.iter().map(|&i| v[i]).collect())
                    }
                    ColumnData::Categorical { codes, cardinality } => ColumnData::Categorical {
                        codes: perm.iter().map(|&i| codes[i]).collect(),
                        cardinality: *cardinality,
                    },
                },
            })
            .collect();
        FeatureTable { columns }
    }

    /// Look up a column by name.
    pub fn column(&self, name: &str) -> Option<&Column> {
        self.columns.iter().find(|c| c.name == name)
    }

    /// Column names in order.
    pub fn column_names(&self) -> Vec<String> {
        self.columns.iter().map(|c| c.name.clone()).collect()
    }

    /// Serialize for a `.sggm` model artifact (KDE support tables).
    pub fn to_json(&self) -> Json {
        let columns = self
            .columns
            .iter()
            .map(|c| match &c.data {
                ColumnData::Continuous(v) => Json::obj(vec![
                    ("name", Json::from(c.name.as_str())),
                    ("kind", Json::from("continuous")),
                    ("values", Json::from(v.clone())),
                ]),
                ColumnData::Categorical { codes, cardinality } => Json::obj(vec![
                    ("name", Json::from(c.name.as_str())),
                    ("kind", Json::from("categorical")),
                    ("cardinality", Json::from(*cardinality)),
                    ("codes", Json::from(codes.clone())),
                ]),
            })
            .collect();
        Json::obj(vec![("columns", Json::Arr(columns))])
    }

    /// Inverse of [`FeatureTable::to_json`]. Cardinalities are restored
    /// verbatim (not re-inferred from the codes), so a loaded table is
    /// indistinguishable from the one that was saved.
    pub fn from_json(v: &Json) -> Result<FeatureTable> {
        let columns = v
            .req_arr("columns")?
            .iter()
            .map(|c| {
                let name = c.req_str("name")?.to_string();
                let data = match c.req_str("kind")? {
                    "continuous" => ColumnData::Continuous(c.req_f64s("values")?),
                    "categorical" => ColumnData::Categorical {
                        codes: c.req_u32s("codes")?,
                        cardinality: c.req_u32("cardinality")?,
                    },
                    other => {
                        return Err(Error::Data(format!(
                            "artifact: unknown column kind `{other}`"
                        )))
                    }
                };
                Ok(Column { name, data })
            })
            .collect::<Result<Vec<Column>>>()?;
        FeatureTable::new(columns)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> FeatureTable {
        FeatureTable::new(vec![
            Column::continuous("amount", vec![1.0, 2.0, 3.0]),
            Column::categorical("kind", vec![0, 1, 0]),
        ])
        .unwrap()
    }

    #[test]
    fn shape_accessors() {
        let t = sample();
        assert_eq!(t.n_rows(), 3);
        assert_eq!(t.n_cols(), 2);
        let (cont, cat) = t.split_indices();
        assert_eq!(cont, vec![0]);
        assert_eq!(cat, vec![1]);
    }

    #[test]
    fn mismatched_lengths_rejected() {
        let r = FeatureTable::new(vec![
            Column::continuous("a", vec![1.0]),
            Column::continuous("b", vec![1.0, 2.0]),
        ]);
        assert!(r.is_err());
    }

    #[test]
    fn row_extraction() {
        let t = sample();
        let (cont, cat) = t.row(1);
        assert_eq!(cont, vec![2.0]);
        assert_eq!(cat, vec![1]);
    }

    #[test]
    fn gather_repeats_and_reorders() {
        let t = sample();
        let g = t.gather(&[2, 2, 0]);
        assert_eq!(g.column("amount").unwrap().as_continuous(), &[3.0, 3.0, 1.0]);
        let (codes, card) = g.column("kind").unwrap().as_categorical();
        assert_eq!(codes, &[0, 0, 0]);
        assert_eq!(card, 2);
    }

    #[test]
    fn cardinality_inferred() {
        let c = Column::categorical("x", vec![3, 1, 2]);
        let (_, card) = c.as_categorical();
        assert_eq!(card, 4);
    }
}
