//! 1-D Gaussian mixture fitted by EM with weight pruning.
//!
//! Stands in for the *variational* Gaussian mixture (VGM) of CTGAN's
//! mode-specific normalization (paper §3.3, following Xu et al. [44]):
//! components whose responsibility mass falls below a threshold are
//! pruned, mimicking the sparsity the variational Dirichlet prior
//! induces, so the number of active modes adapts to the data.

use crate::util::json::Json;
use crate::util::rng::{AliasTable, Pcg64};
use crate::Result;

/// A fitted 1-D Gaussian mixture.
#[derive(Clone, Debug)]
pub struct Gmm {
    /// Component weights (sum to 1).
    pub weights: Vec<f64>,
    /// Component means.
    pub means: Vec<f64>,
    /// Component standard deviations (≥ 1e-6).
    pub stds: Vec<f64>,
}

const MIN_STD: f64 = 1e-6;

fn log_normal_pdf(x: f64, mu: f64, sd: f64) -> f64 {
    let z = (x - mu) / sd;
    -0.5 * z * z - sd.ln() - 0.5 * (2.0 * std::f64::consts::PI).ln()
}

impl Gmm {
    /// Fit with at most `k` components, EM for `iters` iterations,
    /// pruning components with weight < `prune`. Deterministic given
    /// `seed` (used for k-means++-style initialization).
    pub fn fit(data: &[f64], k: usize, iters: usize, prune: f64, seed: u64) -> Gmm {
        let n = data.len();
        if n == 0 {
            return Gmm { weights: vec![1.0], means: vec![0.0], stds: vec![1.0] };
        }
        let k = k.max(1).min(n);
        let mut rng = Pcg64::new(seed);
        // init means from data quantile spread, stds from global std
        let global_mean = crate::util::stats::mean(data);
        let global_std = crate::util::stats::std_dev(data).max(MIN_STD);
        let mut means: Vec<f64> = (0..k)
            .map(|_| data[rng.below_usize(n)])
            .collect();
        let mut stds = vec![global_std; k];
        let mut weights = vec![1.0 / k as f64; k];
        let mut resp = vec![0.0f64; k]; // per-point responsibilities buffer

        for _ in 0..iters {
            // accumulators
            let mut w_acc = vec![0.0f64; k];
            let mut m_acc = vec![0.0f64; k];
            let mut v_acc = vec![0.0f64; k];
            for &x in data {
                // E-step for one point (log-sum-exp)
                let mut max_lp = f64::NEG_INFINITY;
                for j in 0..k {
                    resp[j] = weights[j].max(1e-300).ln() + log_normal_pdf(x, means[j], stds[j]);
                    max_lp = max_lp.max(resp[j]);
                }
                let mut z = 0.0;
                for r in resp.iter_mut() {
                    *r = (*r - max_lp).exp();
                    z += *r;
                }
                for j in 0..k {
                    let r = resp[j] / z;
                    w_acc[j] += r;
                    m_acc[j] += r * x;
                    v_acc[j] += r * x * x;
                }
            }
            // M-step
            for j in 0..k {
                if w_acc[j] > 1e-12 {
                    means[j] = m_acc[j] / w_acc[j];
                    let var = (v_acc[j] / w_acc[j] - means[j] * means[j]).max(MIN_STD * MIN_STD);
                    stds[j] = var.sqrt();
                    weights[j] = w_acc[j] / n as f64;
                } else {
                    // dead component: re-seed on a random point
                    means[j] = data[rng.below_usize(n)];
                    stds[j] = global_std;
                    weights[j] = 1e-6;
                }
            }
            let s: f64 = weights.iter().sum();
            for w in weights.iter_mut() {
                *w /= s;
            }
        }
        let _ = global_mean;

        // prune low-weight components (VGM-style sparsity)
        let keep: Vec<usize> =
            (0..k).filter(|&j| weights[j] >= prune).collect();
        let keep = if keep.is_empty() { vec![0] } else { keep };
        let mut g = Gmm {
            weights: keep.iter().map(|&j| weights[j]).collect(),
            means: keep.iter().map(|&j| means[j]).collect(),
            stds: keep.iter().map(|&j| stds[j]).collect(),
        };
        // merge near-duplicate components: plain EM happily represents one
        // mode with several overlapping Gaussians; the variational prior
        // in CTGAN's VGM collapses those, which we mimic by merging
        // components whose means are within half a pooled std
        g.merge_close();
        let s: f64 = g.weights.iter().sum();
        for w in g.weights.iter_mut() {
            *w /= s;
        }
        g
    }

    /// Merge components whose means differ by less than 0.5 pooled std.
    fn merge_close(&mut self) {
        loop {
            let k = self.n_components();
            if k <= 1 {
                return;
            }
            let mut merged = false;
            'outer: for i in 0..k {
                for j in (i + 1)..k {
                    let pooled = 0.5 * (self.stds[i] + self.stds[j]);
                    if (self.means[i] - self.means[j]).abs() < 0.5 * pooled.max(MIN_STD) {
                        // moment-preserving merge of i and j into i
                        let (wi, wj) = (self.weights[i], self.weights[j]);
                        let w = wi + wj;
                        let mu = (wi * self.means[i] + wj * self.means[j]) / w;
                        let var = (wi * (self.stds[i] * self.stds[i]
                            + (self.means[i] - mu) * (self.means[i] - mu))
                            + wj * (self.stds[j] * self.stds[j]
                                + (self.means[j] - mu) * (self.means[j] - mu)))
                            / w;
                        self.weights[i] = w;
                        self.means[i] = mu;
                        self.stds[i] = var.sqrt().max(MIN_STD);
                        self.weights.remove(j);
                        self.means.remove(j);
                        self.stds.remove(j);
                        merged = true;
                        break 'outer;
                    }
                }
            }
            if !merged {
                return;
            }
        }
    }

    /// Serialize the fitted mixture for a `.sggm` model artifact.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("weights", Json::from(self.weights.clone())),
            ("means", Json::from(self.means.clone())),
            ("stds", Json::from(self.stds.clone())),
        ])
    }

    /// Inverse of [`Gmm::to_json`] — parameters restored verbatim.
    pub fn from_json(v: &Json) -> Result<Gmm> {
        let g = Gmm {
            weights: v.req_f64s("weights")?,
            means: v.req_f64s("means")?,
            stds: v.req_f64s("stds")?,
        };
        if g.weights.is_empty() || g.weights.len() != g.means.len() || g.means.len() != g.stds.len()
        {
            return Err(crate::Error::Data(
                "artifact: gmm component arrays empty or mismatched".into(),
            ));
        }
        Ok(g)
    }

    /// Number of (surviving) components.
    pub fn n_components(&self) -> usize {
        self.weights.len()
    }

    /// Most responsible component for `x` and the in-mode normalized
    /// scalar α = (x − μ)/(4σ) clamped to [−1, 1] (CTGAN convention).
    pub fn encode(&self, x: f64) -> (usize, f64) {
        let mut best = 0;
        let mut best_lp = f64::NEG_INFINITY;
        for j in 0..self.n_components() {
            let lp = self.weights[j].max(1e-300).ln()
                + log_normal_pdf(x, self.means[j], self.stds[j]);
            if lp > best_lp {
                best_lp = lp;
                best = j;
            }
        }
        let alpha = ((x - self.means[best]) / (4.0 * self.stds[best])).clamp(-1.0, 1.0);
        (best, alpha)
    }

    /// Inverse of [`encode`].
    pub fn decode(&self, mode: usize, alpha: f64) -> f64 {
        let mode = mode.min(self.n_components() - 1);
        self.means[mode] + alpha.clamp(-1.0, 1.0) * 4.0 * self.stds[mode]
    }

    /// Sample from the mixture.
    pub fn sample(&self, rng: &mut Pcg64) -> f64 {
        let table = AliasTable::new(&self.weights);
        let j = table.sample(rng);
        rng.normal_ms(self.means[j], self.stds[j])
    }

    /// Mixture log-likelihood of a sample.
    pub fn log_likelihood(&self, data: &[f64]) -> f64 {
        data.iter()
            .map(|&x| {
                let mut max_lp = f64::NEG_INFINITY;
                let lps: Vec<f64> = (0..self.n_components())
                    .map(|j| {
                        let lp = self.weights[j].max(1e-300).ln()
                            + log_normal_pdf(x, self.means[j], self.stds[j]);
                        max_lp = max_lp.max(lp);
                        lp
                    })
                    .collect();
                max_lp + lps.iter().map(|lp| (lp - max_lp).exp()).sum::<f64>().ln()
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bimodal(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Pcg64::new(seed);
        (0..n)
            .map(|i| {
                if i % 2 == 0 {
                    rng.normal_ms(-5.0, 0.5)
                } else {
                    rng.normal_ms(5.0, 0.8)
                }
            })
            .collect()
    }

    #[test]
    fn finds_two_modes() {
        let data = bimodal(2000, 1);
        let g = Gmm::fit(&data, 5, 30, 0.05, 7);
        assert!(g.n_components() >= 2, "k={}", g.n_components());
        // two heaviest components near -5 and 5
        let mut idx: Vec<usize> = (0..g.n_components()).collect();
        idx.sort_by(|&a, &b| g.weights[b].partial_cmp(&g.weights[a]).unwrap());
        let m0 = g.means[idx[0]];
        let m1 = g.means[idx[1]];
        let (lo, hi) = if m0 < m1 { (m0, m1) } else { (m1, m0) };
        assert!((lo + 5.0).abs() < 0.5, "lo={lo}");
        assert!((hi - 5.0).abs() < 0.5, "hi={hi}");
    }

    #[test]
    fn encode_decode_roundtrip() {
        let data = bimodal(1000, 2);
        let g = Gmm::fit(&data, 4, 25, 0.05, 3);
        for &x in data.iter().take(100) {
            let (mode, alpha) = g.encode(x);
            let back = g.decode(mode, alpha);
            assert!((back - x).abs() < 1.0, "x={x} back={back}");
        }
    }

    #[test]
    fn prune_removes_spurious_components() {
        // unimodal data, ask for 8 components, expect pruning to few
        let mut rng = Pcg64::new(3);
        let data: Vec<f64> = (0..1500).map(|_| rng.normal_ms(2.0, 1.0)).collect();
        let g = Gmm::fit(&data, 8, 30, 0.08, 5);
        assert!(g.n_components() <= 4, "k={}", g.n_components());
    }

    #[test]
    fn sample_matches_distribution() {
        let data = bimodal(2000, 4);
        let g = Gmm::fit(&data, 4, 25, 0.05, 6);
        let mut rng = Pcg64::new(8);
        let synth: Vec<f64> = (0..2000).map(|_| g.sample(&mut rng)).collect();
        let m_data = crate::util::stats::mean(&data);
        let m_synth = crate::util::stats::mean(&synth);
        assert!((m_data - m_synth).abs() < 0.5, "{m_data} vs {m_synth}");
        let s_data = crate::util::stats::std_dev(&data);
        let s_synth = crate::util::stats::std_dev(&synth);
        assert!((s_data - s_synth).abs() / s_data < 0.2);
    }

    #[test]
    fn empty_data_safe() {
        let g = Gmm::fit(&[], 3, 10, 0.05, 1);
        assert_eq!(g.n_components(), 1);
        let mut rng = Pcg64::new(1);
        let _ = g.sample(&mut rng);
    }

    #[test]
    fn loglik_improves_with_fit() {
        let data = bimodal(800, 9);
        let fitted = Gmm::fit(&data, 4, 30, 0.05, 2);
        let naive = Gmm { weights: vec![1.0], means: vec![0.0], stds: vec![1.0] };
        assert!(fitted.log_likelihood(&data) > naive.log_likelihood(&data));
    }
}
