//! Multivariate kernel density estimation (Parzen–Rosenblatt), the
//! classical tabular generator the paper ablates against (Table 6: "KDE").
//!
//! Joint product-kernel KDE: a sample is a bootstrap of a full data *row*
//! (preserving inter-column dependence) plus Gaussian kernel noise with
//! Silverman bandwidth on each continuous column; categorical columns
//! keep the row's code with a small smoothing probability of resampling
//! from the empirical marginal.

use super::table::{Column, ColumnData, FeatureTable};
use super::FeatureGenerator;
use crate::util::json::Json;
use crate::util::rng::{AliasTable, Pcg64};
use crate::util::stats;
use crate::Result;

/// Probability a categorical cell is resampled from the marginal
/// (kernel smoothing for discrete columns).
const CAT_SMOOTH: f64 = 0.05;

/// Fixed seed for the deterministic fit-time subsample.
const KDE_SUBSAMPLE_SEED: u64 = 0x6b64_6531;

/// Fitted joint KDE generator.
#[derive(Clone, Debug)]
pub struct KdeFeatureGen {
    /// Bootstrap support (possibly subsampled rows of the input).
    support: FeatureTable,
    /// Bandwidth per column (0 for categorical).
    bandwidths: Vec<f64>,
    /// Marginal tables for categorical smoothing (None for continuous).
    marginals: Vec<Option<(AliasTable, u32)>>,
}

/// Silverman's rule-of-thumb bandwidth.
pub fn silverman_bandwidth(data: &[f64]) -> f64 {
    let n = data.len().max(1) as f64;
    let sd = stats::std_dev(data);
    let iqr = stats::quantile(data, 0.75) - stats::quantile(data, 0.25);
    let sigma = if iqr > 0.0 { sd.min(iqr / 1.34) } else { sd };
    let sigma = if sigma > 0.0 { sigma } else { 1e-3 };
    0.9 * sigma * n.powf(-0.2)
}

impl KdeFeatureGen {
    /// Fit; tables larger than 50k rows are subsampled deterministically.
    pub fn fit(table: &FeatureTable) -> Self {
        const MAX_SAMPLE: usize = 50_000;
        let n = table.n_rows();
        let support = if n > MAX_SAMPLE {
            let mut rng = Pcg64::new(KDE_SUBSAMPLE_SEED);
            let rows: Vec<usize> = (0..MAX_SAMPLE).map(|_| rng.below_usize(n)).collect();
            table.gather(&rows)
        } else {
            table.clone()
        };
        let mut bandwidths = Vec::with_capacity(support.n_cols());
        let mut marginals = Vec::with_capacity(support.n_cols());
        for c in &support.columns {
            match &c.data {
                ColumnData::Continuous(v) => {
                    bandwidths.push(silverman_bandwidth(v));
                    marginals.push(None);
                }
                ColumnData::Categorical { codes, cardinality } => {
                    let mut counts = vec![0.0f64; (*cardinality).max(1) as usize];
                    for &x in codes {
                        counts[x as usize] += 1.0;
                    }
                    bandwidths.push(0.0);
                    marginals.push(Some((AliasTable::new(&counts), *cardinality)));
                }
            }
        }
        KdeFeatureGen { support, bandwidths, marginals }
    }

    /// Reconstruct from a `.sggm` artifact state. The artifact carries
    /// only the bootstrap support table; bandwidths and categorical
    /// marginals are re-derived by refitting, which is deterministic in
    /// the support (the support is already ≤ the subsample cap, so no
    /// further subsampling happens).
    pub fn from_state(state: &Json) -> Result<KdeFeatureGen> {
        let support = FeatureTable::from_json(state.req("support")?)?;
        Ok(KdeFeatureGen::fit(&support))
    }
}

impl FeatureGenerator for KdeFeatureGen {
    fn name(&self) -> &'static str {
        "kde"
    }

    fn save_state(&self) -> Result<Json> {
        Ok(Json::obj(vec![("support", self.support.to_json())]))
    }

    fn sample(&self, n: usize, seed: u64) -> Result<FeatureTable> {
        let mut rng = Pcg64::new(seed);
        let n_sup = self.support.n_rows();
        let mut columns: Vec<Column> = self
            .support
            .columns
            .iter()
            .map(|c| Column {
                name: c.name.clone(),
                data: match &c.data {
                    ColumnData::Continuous(_) => ColumnData::Continuous(Vec::with_capacity(n)),
                    ColumnData::Categorical { cardinality, .. } => ColumnData::Categorical {
                        codes: Vec::with_capacity(n),
                        cardinality: *cardinality,
                    },
                },
            })
            .collect();
        for _ in 0..n {
            let r = if n_sup == 0 { 0 } else { rng.below_usize(n_sup) };
            for (ci, col) in self.support.columns.iter().enumerate() {
                match (&col.data, &mut columns[ci].data) {
                    (ColumnData::Continuous(src), ColumnData::Continuous(dst)) => {
                        let base = if n_sup == 0 { 0.0 } else { src[r] };
                        dst.push(base + rng.normal() * self.bandwidths[ci]);
                    }
                    (ColumnData::Categorical { codes, .. }, ColumnData::Categorical { codes: dst, .. }) => {
                        let (table, _) = self.marginals[ci].as_ref().unwrap();
                        let code = if n_sup == 0 || rng.bool(CAT_SMOOTH) {
                            table.sample(&mut rng) as u32
                        } else {
                            codes[r]
                        };
                        dst.push(code);
                    }
                    _ => unreachable!(),
                }
            }
        }
        FeatureTable::new(columns)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bimodal_table(n: usize) -> FeatureTable {
        let mut rng = Pcg64::new(5);
        let vals: Vec<f64> = (0..n)
            .map(|i| if i % 2 == 0 { rng.normal_ms(-3.0, 0.4) } else { rng.normal_ms(3.0, 0.4) })
            .collect();
        let codes: Vec<u32> = (0..n).map(|_| if rng.bool(0.8) { 0 } else { 1 }).collect();
        FeatureTable::new(vec![
            Column::continuous("v", vals),
            Column::categorical("c", codes),
        ])
        .unwrap()
    }

    fn correlated_table(n: usize) -> FeatureTable {
        let mut rng = Pcg64::new(9);
        let mut a = Vec::new();
        let mut b = Vec::new();
        for _ in 0..n {
            let x = rng.normal();
            a.push(x);
            b.push(2.0 * x + rng.normal() * 0.3);
        }
        FeatureTable::new(vec![Column::continuous("a", a), Column::continuous("b", b)]).unwrap()
    }

    #[test]
    fn preserves_bimodality() {
        let t = bimodal_table(4000);
        let g = KdeFeatureGen::fit(&t);
        let s = g.sample(4000, 1).unwrap();
        let vals = s.column("v").unwrap().as_continuous();
        let near_neg = vals.iter().filter(|&&x| (x + 3.0).abs() < 1.0).count();
        let near_pos = vals.iter().filter(|&&x| (x - 3.0).abs() < 1.0).count();
        assert!(near_neg > 1500 && near_pos > 1500, "{near_neg} {near_pos}");
    }

    #[test]
    fn preserves_inter_column_correlation() {
        // the joint (row-bootstrap) property: a-b correlation survives
        let t = correlated_table(3000);
        let g = KdeFeatureGen::fit(&t);
        let s = g.sample(3000, 3).unwrap();
        let corr_orig = stats::pearson(
            t.column("a").unwrap().as_continuous(),
            t.column("b").unwrap().as_continuous(),
        );
        let corr_synth = stats::pearson(
            s.column("a").unwrap().as_continuous(),
            s.column("b").unwrap().as_continuous(),
        );
        assert!((corr_orig - corr_synth).abs() < 0.1, "{corr_orig} vs {corr_synth}");
    }

    #[test]
    fn categorical_frequencies_preserved() {
        let t = bimodal_table(4000);
        let g = KdeFeatureGen::fit(&t);
        let s = g.sample(4000, 2).unwrap();
        let (codes, _) = s.column("c").unwrap().as_categorical();
        let p0 = codes.iter().filter(|&&c| c == 0).count() as f64 / codes.len() as f64;
        assert!((p0 - 0.8).abs() < 0.05, "p0={p0}");
    }

    #[test]
    fn silverman_positive() {
        assert!(silverman_bandwidth(&[1.0, 2.0, 3.0, 10.0]) > 0.0);
        assert!(silverman_bandwidth(&[5.0, 5.0, 5.0]) > 0.0);
    }

    #[test]
    fn deterministic_sampling() {
        let t = bimodal_table(100);
        let g = KdeFeatureGen::fit(&t);
        assert_eq!(g.sample(20, 9).unwrap(), g.sample(20, 9).unwrap());
    }
}
