//! Multivariate Gaussian feature generator — the feature model used when
//! the GraphWorld baseline is integrated into the framework (paper §4.4:
//! "the feature generators are multi-variate Gaussians").
//!
//! Continuous columns are modeled jointly (mean vector + covariance via
//! Cholesky); categorical columns fall back to their empirical marginals.

use super::table::{Column, ColumnData, FeatureTable};
use super::FeatureGenerator;
use crate::util::json::Json;
use crate::util::rng::{AliasTable, Pcg64};
use crate::util::stats;
use crate::{Error, Result};

/// Fitted multivariate Gaussian over the continuous columns.
#[derive(Clone, Debug)]
pub struct GaussianFeatureGen {
    cont_names: Vec<String>,
    mean: Vec<f64>,
    /// Lower Cholesky factor of the covariance, row-major d×d.
    chol: Vec<f64>,
    d: usize,
    cats: Vec<(String, AliasTable, u32)>,
    /// Column order of the original table, to reconstruct layout.
    order: Vec<(bool, usize)>, // (is_continuous, index within kind)
}

impl GaussianFeatureGen {
    /// Fit mean/covariance on continuous columns and empirical marginals
    /// on categorical columns.
    pub fn fit(table: &FeatureTable) -> Result<Self> {
        let mut cont_cols: Vec<(&str, &[f64])> = Vec::new();
        let mut cats = Vec::new();
        let mut order = Vec::new();
        for c in &table.columns {
            match &c.data {
                ColumnData::Continuous(v) => {
                    order.push((true, cont_cols.len()));
                    cont_cols.push((&c.name, v));
                }
                ColumnData::Categorical { codes, cardinality } => {
                    let mut counts = vec![0.0f64; *cardinality as usize];
                    for &x in codes {
                        counts[x as usize] += 1.0;
                    }
                    order.push((false, cats.len()));
                    cats.push((c.name.clone(), AliasTable::new(&counts), *cardinality));
                }
            }
        }
        let d = cont_cols.len();
        let n = table.n_rows();
        let mean: Vec<f64> = cont_cols.iter().map(|(_, v)| stats::mean(v)).collect();
        // covariance with diagonal jitter
        let mut cov = vec![0.0f64; d * d];
        for i in 0..d {
            for j in i..d {
                let mut s = 0.0;
                for r in 0..n {
                    s += (cont_cols[i].1[r] - mean[i]) * (cont_cols[j].1[r] - mean[j]);
                }
                let c = if n > 1 { s / (n - 1) as f64 } else { 1.0 };
                cov[i * d + j] = c;
                cov[j * d + i] = c;
            }
        }
        for i in 0..d {
            cov[i * d + i] += 1e-9;
        }
        let chol = if d > 0 {
            stats::cholesky(&cov, d).map_err(crate::Error::Numeric)?
        } else {
            Vec::new()
        };
        Ok(GaussianFeatureGen {
            cont_names: cont_cols.iter().map(|(n, _)| n.to_string()).collect(),
            mean,
            chol,
            d,
            cats,
            order,
        })
    }

    /// Reconstruct from a `.sggm` artifact state. The categorical alias
    /// tables are restored from their internal `(prob, alias)` arrays,
    /// bit-exact w.r.t. the fitted generator.
    pub fn from_state(state: &Json) -> Result<GaussianFeatureGen> {
        let cats = state
            .req_arr("cats")?
            .iter()
            .map(|c| {
                let prob = c.req_f64s("prob")?;
                let alias = c.req_u32s("alias")?;
                if prob.len() != alias.len() {
                    return Err(Error::Data(
                        "artifact: alias-table prob/alias length mismatch".into(),
                    ));
                }
                Ok((
                    c.req_str("name")?.to_string(),
                    AliasTable::from_parts(prob, alias),
                    c.req_u32("cardinality")?,
                ))
            })
            .collect::<Result<Vec<_>>>()?;
        let order = state
            .req_arr("order")?
            .iter()
            .map(|o| Ok((o.req_bool("continuous")?, o.req_usize("index")?)))
            .collect::<Result<Vec<(bool, usize)>>>()?;
        let g = GaussianFeatureGen {
            cont_names: state.req_strs("cont_names")?,
            mean: state.req_f64s("mean")?,
            chol: state.req_f64s("chol")?,
            d: state.req_usize("d")?,
            cats,
            order,
        };
        // cross-field shape invariants: reject at load time rather than
        // panicking with an index error at sample time
        let d = g.d;
        if g.mean.len() != d || g.chol.len() != d * d || g.cont_names.len() != d {
            return Err(Error::Data(format!(
                "artifact: gaussian state shapes inconsistent (d={d}, mean={}, chol={}, \
                 cont_names={})",
                g.mean.len(),
                g.chol.len(),
                g.cont_names.len()
            )));
        }
        let bad_order = g.order.iter().any(|&(is_cont, idx)| {
            if is_cont {
                idx >= d
            } else {
                idx >= g.cats.len()
            }
        });
        if bad_order || g.order.len() != d + g.cats.len() {
            return Err(Error::Data(
                "artifact: gaussian column order indices out of range".into(),
            ));
        }
        Ok(g)
    }
}

impl FeatureGenerator for GaussianFeatureGen {
    fn name(&self) -> &'static str {
        "gaussian"
    }

    fn save_state(&self) -> Result<Json> {
        let cats = self
            .cats
            .iter()
            .map(|(name, table, card)| {
                let (prob, alias) = table.to_parts();
                Json::obj(vec![
                    ("name", Json::from(name.as_str())),
                    ("prob", Json::from(prob.to_vec())),
                    ("alias", Json::from(alias.to_vec())),
                    ("cardinality", Json::from(*card)),
                ])
            })
            .collect();
        let order = self
            .order
            .iter()
            .map(|&(is_cont, idx)| {
                Json::obj(vec![
                    ("continuous", Json::from(is_cont)),
                    ("index", Json::from(idx)),
                ])
            })
            .collect();
        let cont_names =
            Json::Arr(self.cont_names.iter().map(|n| Json::from(n.as_str())).collect());
        Ok(Json::obj(vec![
            ("cont_names", cont_names),
            ("mean", Json::from(self.mean.clone())),
            ("chol", Json::from(self.chol.clone())),
            ("d", Json::from(self.d)),
            ("cats", Json::Arr(cats)),
            ("order", Json::Arr(order)),
        ]))
    }

    fn sample(&self, n: usize, seed: u64) -> Result<FeatureTable> {
        let mut rng = Pcg64::new(seed);
        let d = self.d;
        // continuous: x = mean + L z
        let mut cont: Vec<Vec<f64>> = vec![Vec::with_capacity(n); d];
        let mut z = vec![0.0f64; d];
        for _ in 0..n {
            for zi in z.iter_mut() {
                *zi = rng.normal();
            }
            for i in 0..d {
                let mut x = self.mean[i];
                for k in 0..=i {
                    x += self.chol[i * d + k] * z[k];
                }
                cont[i].push(x);
            }
        }
        let mut cat: Vec<Vec<u32>> = Vec::with_capacity(self.cats.len());
        for (_, table, _) in &self.cats {
            cat.push((0..n).map(|_| table.sample(&mut rng) as u32).collect());
        }
        let mut columns = Vec::with_capacity(self.order.len());
        for &(is_cont, idx) in &self.order {
            if is_cont {
                columns.push(Column {
                    name: self.cont_names[idx].clone(),
                    data: ColumnData::Continuous(std::mem::take(&mut cont[idx])),
                });
            } else {
                let (name, _, card) = &self.cats[idx];
                columns.push(Column {
                    name: name.clone(),
                    data: ColumnData::Categorical {
                        codes: std::mem::take(&mut cat[idx]),
                        cardinality: *card,
                    },
                });
            }
        }
        FeatureTable::new(columns)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn correlated_table(n: usize) -> FeatureTable {
        let mut rng = Pcg64::new(3);
        let mut a = Vec::with_capacity(n);
        let mut b = Vec::with_capacity(n);
        for _ in 0..n {
            let x = rng.normal();
            a.push(2.0 * x + 1.0);
            b.push(-x + rng.normal() * 0.3);
        }
        FeatureTable::new(vec![
            Column::continuous("a", a),
            Column::continuous("b", b),
            Column::categorical("c", (0..n).map(|i| (i % 3) as u32).collect()),
        ])
        .unwrap()
    }

    #[test]
    fn preserves_correlation() {
        let t = correlated_table(3000);
        let g = GaussianFeatureGen::fit(&t).unwrap();
        let s = g.sample(3000, 1).unwrap();
        let corr_orig = stats::pearson(
            t.column("a").unwrap().as_continuous(),
            t.column("b").unwrap().as_continuous(),
        );
        let corr_synth = stats::pearson(
            s.column("a").unwrap().as_continuous(),
            s.column("b").unwrap().as_continuous(),
        );
        assert!((corr_orig - corr_synth).abs() < 0.05, "{corr_orig} vs {corr_synth}");
    }

    #[test]
    fn preserves_mean_and_layout() {
        let t = correlated_table(2000);
        let g = GaussianFeatureGen::fit(&t).unwrap();
        let s = g.sample(2000, 2).unwrap();
        assert_eq!(s.columns[0].name, "a");
        assert_eq!(s.columns[2].name, "c");
        let m = stats::mean(s.column("a").unwrap().as_continuous());
        assert!((m - 1.0).abs() < 0.15, "m={m}");
    }

    #[test]
    fn categorical_marginal_preserved() {
        let t = correlated_table(3000);
        let g = GaussianFeatureGen::fit(&t).unwrap();
        let s = g.sample(3000, 5).unwrap();
        let (codes, card) = s.column("c").unwrap().as_categorical();
        assert_eq!(card, 3);
        let p0 = codes.iter().filter(|&&c| c == 0).count() as f64 / codes.len() as f64;
        assert!((p0 - 1.0 / 3.0).abs() < 0.05);
    }

    #[test]
    fn no_continuous_columns_ok() {
        let t = FeatureTable::new(vec![Column::categorical("only", vec![0, 1, 1, 0])]).unwrap();
        let g = GaussianFeatureGen::fit(&t).unwrap();
        let s = g.sample(10, 1).unwrap();
        assert_eq!(s.n_rows(), 10);
    }
}
