//! The CTGAN-style feature GAN (paper §3.3).
//!
//! The compute lives in the AOT-compiled JAX/Pallas artifacts (L1/L2);
//! this module owns the *coordinator-side* logic: the mode-specific
//! encoder, batching of encoded rows, driving the backend train step, and
//! decoding generated samples back into a [`FeatureTable`].
//!
//! The backend is abstracted by [`GanBackend`] so the pipeline and tests
//! can run without the PJRT runtime ([`ResampleBackend`]); the real
//! backend is [`crate::runtime::gan_exec::PjrtGanBackend`], which executes
//! `gan_train_step` / `gan_sample` HLO artifacts on the PJRT CPU client.

use super::encoder::ModeSpecificEncoder;
use super::table::FeatureTable;
use super::FeatureGenerator;
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use crate::{Error, Result};

/// Abstract GAN compute backend over encoded rows.
pub trait GanBackend {
    /// Backend name for logs/tables.
    fn name(&self) -> &'static str;

    /// Train on the encoded matrix (`n_rows × width`, row-major).
    fn train(&mut self, encoded: &[f32], n_rows: usize, width: usize, seed: u64) -> Result<()>;

    /// Generate `n` encoded rows of the given width.
    fn sample(&self, n: usize, width: usize, seed: u64) -> Result<Vec<f32>>;

    /// Serialize the trained backend for a `.sggm` model artifact.
    /// Backends whose state lives outside the process (PJRT device
    /// buffers) keep this default rejection — their pipelines cannot be
    /// exported until the weights are host-transferable.
    fn save_state(&self) -> Result<Json> {
        Err(Error::Config(format!(
            "gan backend `{}` cannot be serialized into a model artifact",
            self.name()
        )))
    }
}

/// Test/fallback backend: memorizes the encoded training rows and samples
/// them with small jitter on the α slots. Exercises the exact same
/// encode→train→sample→decode path as the PJRT backend.
#[derive(Default)]
pub struct ResampleBackend {
    rows: Vec<f32>,
    width: usize,
}

impl GanBackend for ResampleBackend {
    fn name(&self) -> &'static str {
        "resample"
    }

    fn train(&mut self, encoded: &[f32], _n_rows: usize, width: usize, _seed: u64) -> Result<()> {
        self.rows = encoded.to_vec();
        self.width = width;
        Ok(())
    }

    fn sample(&self, n: usize, width: usize, seed: u64) -> Result<Vec<f32>> {
        let n_rows = if self.width == 0 { 0 } else { self.rows.len() / self.width };
        let mut rng = Pcg64::new(seed);
        let mut out = vec![0.0f32; n * width];
        for r in 0..n {
            if n_rows == 0 {
                continue;
            }
            let src = rng.below_usize(n_rows);
            let row = &self.rows[src * self.width..(src + 1) * self.width];
            let take = width.min(self.width);
            out[r * width..r * width + take].copy_from_slice(&row[..take]);
        }
        Ok(out)
    }

    fn save_state(&self) -> Result<Json> {
        // f32 → f64 is exact, so the memorized rows round-trip bit-exact
        Ok(Json::obj(vec![
            ("rows", Json::Arr(self.rows.iter().map(|&x| Json::from(x)).collect())),
            ("width", Json::from(self.width)),
        ]))
    }
}

impl ResampleBackend {
    /// Reconstruct from a `.sggm` artifact state.
    pub fn from_state(state: &Json) -> Result<ResampleBackend> {
        let rows = state
            .req_arr("rows")?
            .iter()
            .map(|v| {
                v.as_f64().map(|x| x as f32).ok_or_else(|| {
                    Error::Data("artifact: gan `rows` must hold numbers".into())
                })
            })
            .collect::<Result<Vec<f32>>>()?;
        Ok(ResampleBackend { rows, width: state.req_usize("width")? })
    }
}

/// Feature GAN: encoder + backend.
pub struct GanFeatureGen {
    encoder: ModeSpecificEncoder,
    backend: Box<dyn GanBackend>,
}

impl GanFeatureGen {
    /// Fit the encoder on `table`, then train `backend` on the encoding.
    pub fn fit_with_backend(
        table: &FeatureTable,
        mut backend: Box<dyn GanBackend>,
        seed: u64,
    ) -> Result<GanFeatureGen> {
        let encoder = ModeSpecificEncoder::fit(table);
        let encoded = encoder.encode(table)?;
        backend.train(&encoded, table.n_rows(), encoder.width(), seed)?;
        Ok(GanFeatureGen { encoder, backend })
    }

    /// Fit with the in-process resample backend (no artifacts needed).
    pub fn fit_resample(table: &FeatureTable, seed: u64) -> Result<GanFeatureGen> {
        Self::fit_with_backend(table, Box::new(ResampleBackend::default()), seed)
    }

    /// Encoded width (for runtime artifact selection).
    pub fn width(&self) -> usize {
        self.encoder.width()
    }

    /// Backend name.
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Reconstruct from a `.sggm` artifact state (encoder + serialized
    /// backend). Only host-resident backends appear in artifacts — see
    /// [`GanBackend::save_state`].
    pub fn from_state(state: &Json) -> Result<GanFeatureGen> {
        let encoder = ModeSpecificEncoder::from_json(state.req("encoder")?)?;
        let b = state.req("backend")?;
        let backend: Box<dyn GanBackend> = match b.req_str("kind")? {
            "resample" => Box::new(ResampleBackend::from_state(b.req("state")?)?),
            other => {
                return Err(Error::Data(format!(
                    "artifact: unknown gan backend `{other}`; loadable: resample"
                )))
            }
        };
        Ok(GanFeatureGen { encoder, backend })
    }
}

impl FeatureGenerator for GanFeatureGen {
    fn name(&self) -> &'static str {
        "gan"
    }

    fn save_state(&self) -> Result<Json> {
        Ok(Json::obj(vec![
            ("encoder", self.encoder.to_json()),
            (
                "backend",
                Json::obj(vec![
                    ("kind", Json::from(self.backend.name())),
                    ("state", self.backend.save_state()?),
                ]),
            ),
        ]))
    }

    fn sample(&self, n: usize, seed: u64) -> Result<FeatureTable> {
        let encoded = self.backend.sample(n, self.encoder.width(), seed)?;
        self.encoder.decode(&encoded, n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::featgen::table::Column;
    use crate::util::stats;

    fn table() -> FeatureTable {
        let mut rng = Pcg64::new(4);
        let vals: Vec<f64> = (0..1000)
            .map(|i| if i % 3 == 0 { rng.normal_ms(10.0, 1.0) } else { rng.normal_ms(-2.0, 0.5) })
            .collect();
        let codes: Vec<u32> = (0..1000).map(|_| if rng.bool(0.7) { 0 } else { 1 }).collect();
        FeatureTable::new(vec![
            Column::continuous("v", vals),
            Column::categorical("c", codes),
        ])
        .unwrap()
    }

    #[test]
    fn resample_backend_roundtrip_preserves_distribution() {
        let t = table();
        let g = GanFeatureGen::fit_resample(&t, 1).unwrap();
        let s = g.sample(1000, 2).unwrap();
        assert_eq!(s.n_rows(), 1000);
        let mo = stats::mean(t.column("v").unwrap().as_continuous());
        let ms = stats::mean(s.column("v").unwrap().as_continuous());
        assert!((mo - ms).abs() < 1.0, "{mo} vs {ms}");
        let (codes, _) = s.column("c").unwrap().as_categorical();
        let p0 = codes.iter().filter(|&&c| c == 0).count() as f64 / 1000.0;
        assert!((p0 - 0.7).abs() < 0.08, "p0={p0}");
    }

    #[test]
    fn sample_is_deterministic() {
        let t = table();
        let g = GanFeatureGen::fit_resample(&t, 1).unwrap();
        assert_eq!(g.sample(50, 9).unwrap(), g.sample(50, 9).unwrap());
    }

    #[test]
    fn width_positive() {
        let t = table();
        let g = GanFeatureGen::fit_resample(&t, 1).unwrap();
        assert!(g.width() >= 4);
    }
}
