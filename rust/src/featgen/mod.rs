//! Feature generation (paper §3.3).
//!
//! Node/edge features are treated as a tabular dataset ([`table`]) of
//! continuous and categorical columns. Four interchangeable generators
//! implement [`FeatureGenerator`]:
//!
//! * [`gan`] — the paper's CTGAN-style GAN: mode-specific normalization
//!   ([`encoder`], backed by the [`gmm`] EM mixture standing in for the
//!   variational GM), feature tokenizer + ResNet stacks in JAX/Pallas,
//!   trained and sampled through the PJRT runtime.
//! * [`kde`] — per-column kernel density estimation (the classical
//!   tabular baseline, Table 6 ablation).
//! * [`random`] — ranges-only random generator (the paper's "random").
//! * [`gaussian`] — multivariate Gaussian (the feature model used when
//!   integrating GraphWorld into the framework, §4.4).

pub mod encoder;
pub mod gan;
pub mod gaussian;
pub mod gmm;
pub mod kde;
pub mod random;
pub mod table;

pub use table::{Column, ColumnData, FeatureTable};

use crate::Result;

/// A fitted tabular feature generator.
pub trait FeatureGenerator {
    /// Name used in experiment tables.
    fn name(&self) -> &'static str;

    /// Sample `n` feature rows.
    fn sample(&self, n: usize, seed: u64) -> Result<FeatureTable>;
}

/// Which feature generator a pipeline uses (ablation axis of Table 6).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FeatKind {
    /// CTGAN-style GAN (requires AOT artifacts).
    Gan,
    /// Kernel density estimation.
    Kde,
    /// Ranges-only random.
    Random,
    /// Multivariate Gaussian.
    Gaussian,
}

impl std::str::FromStr for FeatKind {
    type Err = String;
    fn from_str(s: &str) -> std::result::Result<Self, String> {
        match s {
            "gan" => Ok(FeatKind::Gan),
            "kde" => Ok(FeatKind::Kde),
            "random" => Ok(FeatKind::Random),
            "gaussian" | "mvg" => Ok(FeatKind::Gaussian),
            other => Err(format!("unknown feature generator `{other}`")),
        }
    }
}
