//! Feature generation (paper §3.3).
//!
//! Node/edge features are treated as a tabular dataset ([`table`]) of
//! continuous and categorical columns. Four interchangeable generators
//! implement [`FeatureGenerator`]:
//!
//! * [`gan`] — the paper's CTGAN-style GAN: mode-specific normalization
//!   ([`encoder`], backed by the [`gmm`] EM mixture standing in for the
//!   variational GM), feature tokenizer + ResNet stacks in JAX/Pallas,
//!   trained and sampled through the PJRT runtime.
//! * [`kde`] — per-column kernel density estimation (the classical
//!   tabular baseline, Table 6 ablation).
//! * [`random`] — ranges-only random generator (the paper's "random").
//! * [`gaussian`] — multivariate Gaussian (the feature model used when
//!   integrating GraphWorld into the framework, §4.4).
//!
//! Backends register in the pipeline's feature [`Registry`] via
//! [`register_builtins`]; the same registry entry serves edge- and
//! node-feature legs (a factory is handed whichever table it must fit).

pub mod encoder;
pub mod gan;
pub mod gaussian;
pub mod gmm;
pub mod kde;
pub mod random;
pub mod table;

pub use table::{Column, ColumnData, FeatureTable};

use crate::pipeline::registry::Registry;
use crate::pipeline::spec::Params;
use crate::util::json::Json;
use crate::Result;

/// A fitted tabular feature generator.
pub trait FeatureGenerator {
    /// Name used in experiment tables.
    fn name(&self) -> &'static str;

    /// Sample `n` feature rows.
    fn sample(&self, n: usize, seed: u64) -> Result<FeatureTable>;

    /// Serialize the fitted state for a `.sggm` model artifact. The
    /// state loader registered under [`Self::name`] must reconstruct a
    /// generator whose sampling is bit-identical for every seed.
    fn save_state(&self) -> Result<Json>;
}

/// Everything a feature factory sees at fit time.
pub struct FeatureFitContext<'a> {
    /// The feature table to fit on (edge or node features).
    pub table: &'a FeatureTable,
    /// Backend parameters from the scenario spec / builder.
    pub params: &'a Params,
    /// Fitting seed.
    pub seed: u64,
}

/// Factory signature for registry-registered feature backends.
pub type FeatureGeneratorFactory =
    fn(&FeatureFitContext<'_>) -> Result<Box<dyn FeatureGenerator>>;

fn make_random(ctx: &FeatureFitContext<'_>) -> Result<Box<dyn FeatureGenerator>> {
    Ok(Box::new(random::RandomFeatureGen::fit(ctx.table)))
}

fn make_kde(ctx: &FeatureFitContext<'_>) -> Result<Box<dyn FeatureGenerator>> {
    Ok(Box::new(kde::KdeFeatureGen::fit(ctx.table)))
}

fn make_gaussian(ctx: &FeatureFitContext<'_>) -> Result<Box<dyn FeatureGenerator>> {
    Ok(Box::new(gaussian::GaussianFeatureGen::fit(ctx.table)?))
}

fn make_gan(ctx: &FeatureFitContext<'_>) -> Result<Box<dyn FeatureGenerator>> {
    let use_pjrt = ctx.params.bool_or("use_pjrt", true)?;
    if use_pjrt && crate::runtime::artifacts_available() {
        let rt = crate::runtime::global()?;
        let backend = crate::runtime::gan_exec::PjrtGanBackend::new(
            rt,
            crate::runtime::gan_exec::GanTrainConfig::default(),
        )?;
        Ok(Box::new(gan::GanFeatureGen::fit_with_backend(
            ctx.table,
            Box::new(backend),
            ctx.seed,
        )?))
    } else {
        if use_pjrt {
            crate::warn_log!("artifacts missing: GAN falls back to resample backend");
        }
        Ok(Box::new(gan::GanFeatureGen::fit_resample(ctx.table, ctx.seed)?))
    }
}

/// Register every built-in feature backend into `reg`.
pub fn register_builtins(reg: &mut Registry<FeatureGeneratorFactory>) {
    reg.register("random", make_random);
    reg.register("kde", make_kde);
    reg.register("gaussian", make_gaussian);
    reg.register("gan", make_gan);
    reg.alias("mvg", "gaussian");
}

/// Loader signature for `.sggm` artifact state: the inverse of
/// [`FeatureGenerator::save_state`], keyed by backend name.
pub type FeatureStateLoader = fn(&Json) -> Result<Box<dyn FeatureGenerator>>;

fn load_random(state: &Json) -> Result<Box<dyn FeatureGenerator>> {
    Ok(Box::new(random::RandomFeatureGen::from_state(state)?))
}

fn load_kde(state: &Json) -> Result<Box<dyn FeatureGenerator>> {
    Ok(Box::new(kde::KdeFeatureGen::from_state(state)?))
}

fn load_gaussian(state: &Json) -> Result<Box<dyn FeatureGenerator>> {
    Ok(Box::new(gaussian::GaussianFeatureGen::from_state(state)?))
}

fn load_gan(state: &Json) -> Result<Box<dyn FeatureGenerator>> {
    Ok(Box::new(gan::GanFeatureGen::from_state(state)?))
}

/// Register every built-in feature state loader (keys mirror
/// [`register_builtins`]).
pub fn register_state_loaders(reg: &mut Registry<FeatureStateLoader>) {
    reg.register("random", load_random);
    reg.register("kde", load_kde);
    reg.register("gaussian", load_gaussian);
    reg.register("gan", load_gan);
    reg.alias("mvg", "gaussian");
}

/// Which feature generator a pipeline uses (ablation axis of Table 6).
/// Legacy closed enum — new code names backends by registry string.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FeatKind {
    /// CTGAN-style GAN (requires AOT artifacts).
    Gan,
    /// Kernel density estimation.
    Kde,
    /// Ranges-only random.
    Random,
    /// Multivariate Gaussian.
    Gaussian,
}

impl FeatKind {
    /// Canonical registry name of this kind.
    pub fn registry_name(&self) -> &'static str {
        match self {
            FeatKind::Gan => "gan",
            FeatKind::Kde => "kde",
            FeatKind::Random => "random",
            FeatKind::Gaussian => "gaussian",
        }
    }
}

impl std::str::FromStr for FeatKind {
    type Err = String;
    fn from_str(s: &str) -> std::result::Result<Self, String> {
        match s {
            "gan" => Ok(FeatKind::Gan),
            "kde" => Ok(FeatKind::Kde),
            "random" => Ok(FeatKind::Random),
            "gaussian" | "mvg" => Ok(FeatKind::Gaussian),
            other => Err(format!("unknown feature generator `{other}`")),
        }
    }
}
