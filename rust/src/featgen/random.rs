//! Range-fitted random feature generator — the paper's "random" baseline
//! (§4.1: "a random feature generator with ranges fitted to the original
//! feature dimension").

use super::table::{Column, ColumnData, FeatureTable};
use super::FeatureGenerator;
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use crate::{Error, Result};

/// Per-column fitted ranges.
#[derive(Clone, Debug)]
pub struct RandomFeatureGen {
    specs: Vec<ColumnSpec>,
}

#[derive(Clone, Debug)]
enum ColumnSpec {
    Continuous { name: String, lo: f64, hi: f64 },
    Categorical { name: String, cardinality: u32 },
}

impl RandomFeatureGen {
    /// Fit: record each column's range / cardinality.
    pub fn fit(table: &FeatureTable) -> Self {
        let specs = table
            .columns
            .iter()
            .map(|c| match &c.data {
                ColumnData::Continuous(v) => {
                    let (lo, hi) = crate::util::stats::min_max(v);
                    ColumnSpec::Continuous { name: c.name.clone(), lo, hi }
                }
                ColumnData::Categorical { cardinality, .. } => {
                    ColumnSpec::Categorical { name: c.name.clone(), cardinality: *cardinality }
                }
            })
            .collect();
        RandomFeatureGen { specs }
    }

    /// Reconstruct from a `.sggm` artifact state.
    pub fn from_state(state: &Json) -> Result<RandomFeatureGen> {
        let specs = state
            .req_arr("columns")?
            .iter()
            .map(|c| {
                let name = c.req_str("name")?.to_string();
                match c.req_str("kind")? {
                    "continuous" => Ok(ColumnSpec::Continuous {
                        name,
                        lo: c.req_f64("lo")?,
                        hi: c.req_f64("hi")?,
                    }),
                    "categorical" => Ok(ColumnSpec::Categorical {
                        name,
                        cardinality: c.req_u32("cardinality")?,
                    }),
                    other => Err(Error::Data(format!(
                        "artifact: unknown random-featgen column kind `{other}`"
                    ))),
                }
            })
            .collect::<Result<Vec<ColumnSpec>>>()?;
        Ok(RandomFeatureGen { specs })
    }
}

impl FeatureGenerator for RandomFeatureGen {
    fn name(&self) -> &'static str {
        "random"
    }

    fn save_state(&self) -> Result<Json> {
        let columns = self
            .specs
            .iter()
            .map(|s| match s {
                ColumnSpec::Continuous { name, lo, hi } => Json::obj(vec![
                    ("name", Json::from(name.as_str())),
                    ("kind", Json::from("continuous")),
                    ("lo", Json::from(*lo)),
                    ("hi", Json::from(*hi)),
                ]),
                ColumnSpec::Categorical { name, cardinality } => Json::obj(vec![
                    ("name", Json::from(name.as_str())),
                    ("kind", Json::from("categorical")),
                    ("cardinality", Json::from(*cardinality)),
                ]),
            })
            .collect();
        Ok(Json::obj(vec![("columns", Json::Arr(columns))]))
    }

    fn sample(&self, n: usize, seed: u64) -> Result<FeatureTable> {
        let mut rng = Pcg64::new(seed);
        let columns = self
            .specs
            .iter()
            .map(|s| match s {
                ColumnSpec::Continuous { name, lo, hi } => Column {
                    name: name.clone(),
                    data: ColumnData::Continuous((0..n).map(|_| rng.range(*lo, *hi)).collect()),
                },
                ColumnSpec::Categorical { name, cardinality } => Column {
                    name: name.clone(),
                    data: ColumnData::Categorical {
                        codes: (0..n).map(|_| rng.below(*cardinality.max(&1) as u64) as u32).collect(),
                        cardinality: *cardinality,
                    },
                },
            })
            .collect();
        FeatureTable::new(columns)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> FeatureTable {
        FeatureTable::new(vec![
            Column::continuous("x", vec![-2.0, 0.0, 4.0]),
            Column::categorical("c", vec![0, 2, 1]),
        ])
        .unwrap()
    }

    #[test]
    fn respects_ranges() {
        let g = RandomFeatureGen::fit(&table());
        let s = g.sample(500, 1).unwrap();
        for &v in s.column("x").unwrap().as_continuous() {
            assert!((-2.0..=4.0).contains(&v));
        }
        let (codes, card) = s.column("c").unwrap().as_categorical();
        assert_eq!(card, 3);
        assert!(codes.iter().all(|&c| c < 3));
    }

    #[test]
    fn sample_shape() {
        let g = RandomFeatureGen::fit(&table());
        let s = g.sample(17, 2).unwrap();
        assert_eq!(s.n_rows(), 17);
        assert_eq!(s.n_cols(), 2);
    }

    #[test]
    fn deterministic() {
        let g = RandomFeatureGen::fit(&table());
        assert_eq!(g.sample(10, 3).unwrap(), g.sample(10, 3).unwrap());
    }
}
