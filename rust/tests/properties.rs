//! Randomized property tests over coordinator invariants (hand-rolled
//! driver in `sgg::util::proptest` — the proptest crate is unavailable
//! offline). Each property runs across many seeded cases and reports the
//! failing seed for replay.

use sgg::graph::{EdgeList, PartiteSpec};
use sgg::prop_assert;
use sgg::structgen::chunked::{generate_chunked_collect, ChunkConfig};
use sgg::structgen::kronecker::KroneckerGen;
use sgg::structgen::theta::ThetaS;
use sgg::structgen::StructureGenerator;
use sgg::util::proptest::check;
use sgg::util::rng::Pcg64;

fn random_theta(rng: &mut Pcg64) -> ThetaS {
    ThetaS::new(
        rng.range(0.1, 0.7),
        rng.range(0.05, 0.3),
        rng.range(0.05, 0.3),
        rng.range(0.02, 0.2),
    )
}

#[test]
fn prop_kronecker_respects_bounds_and_count() {
    check("kronecker bounds", 25, |rng| {
        let theta = random_theta(rng);
        let n_src = 1u64 << (3 + rng.below(8));
        let n_dst = 1u64 << (3 + rng.below(8));
        let edges = 500 + rng.below(5_000);
        let gen = KroneckerGen::new(theta, PartiteSpec::bipartite(n_src, n_dst), edges);
        let g = gen.generate(1, rng.next_u64()).map_err(|e| e.to_string())?;
        prop_assert!(g.len() as u64 == edges, "count {} != {edges}", g.len());
        prop_assert!(g.validate().is_ok(), "bounds violated");
        Ok(())
    });
}

#[test]
fn prop_chunked_equals_direct_as_multiset() {
    check("chunked == direct multiset", 10, |rng| {
        let theta = random_theta(rng);
        let n = 1u64 << (6 + rng.below(5));
        let edges = 2_000 + rng.below(6_000);
        let seed = rng.next_u64();
        let gen = KroneckerGen::new(theta, PartiteSpec::square(n), edges);
        let cfg = ChunkConfig {
            prefix_levels: 1 + rng.below(3) as u32,
            workers: 1 + rng.below_usize(6),
            queue_capacity: 1 + rng.below_usize(4),
            ..ChunkConfig::default()
        };
        let chunked = generate_chunked_collect(&gen, n, n, edges, seed, cfg)
            .map_err(|e| e.to_string())?;
        prop_assert!(chunked.len() as u64 == edges, "chunked count");
        prop_assert!(chunked.validate().is_ok(), "chunked bounds");
        // determinism across worker counts
        let cfg2 = ChunkConfig { workers: 1, ..cfg };
        let mut a = generate_chunked_collect(&gen, n, n, edges, seed, cfg)
            .map_err(|e| e.to_string())?;
        let mut b = generate_chunked_collect(&gen, n, n, edges, seed, cfg2)
            .map_err(|e| e.to_string())?;
        a.sort_dedup();
        b.sort_dedup();
        prop_assert!(a.src == b.src && a.dst == b.dst, "worker count changed output");
        Ok(())
    });
}

#[test]
fn prop_sort_dedup_idempotent_and_sorted() {
    check("sort_dedup idempotent", 30, |rng| {
        let n = 1 + rng.below(200);
        let mut e = EdgeList::new(PartiteSpec::square(n));
        for _ in 0..rng.below(2_000) {
            e.push(rng.below(n), rng.below(n));
        }
        e.sort_dedup();
        let (src1, dst1) = (e.src.clone(), e.dst.clone());
        let removed = e.sort_dedup();
        prop_assert!(removed == 0, "second dedup removed {removed}");
        prop_assert!(e.src == src1 && e.dst == dst1, "not idempotent");
        for w in e.iter().collect::<Vec<_>>().windows(2) {
            prop_assert!(w[0] <= w[1], "not sorted: {:?}", w);
        }
        Ok(())
    });
}

#[test]
fn prop_metrics_identity_and_range() {
    check("metric identity/range", 12, |rng| {
        let theta = random_theta(rng);
        let n = 1u64 << (6 + rng.below(4));
        let gen = KroneckerGen::new(theta, PartiteSpec::square(n), 3_000);
        let g = gen.generate(1, rng.next_u64()).map_err(|e| e.to_string())?;
        let s = sgg::metrics::degree::degree_dist_score(&g, &g);
        prop_assert!((s - 1.0).abs() < 1e-9, "self-score {s} != 1");
        let h = gen.generate(1, rng.next_u64()).map_err(|e| e.to_string())?;
        let s2 = sgg::metrics::degree::degree_dist_score(&g, &h);
        prop_assert!((0.0..=1.0).contains(&s2), "score {s2} out of range");
        let d = sgg::metrics::degree::dcc(&g, &h, 12);
        prop_assert!((0.0..=1.0).contains(&d), "dcc {d} out of range");
        Ok(())
    });
}

#[test]
fn prop_feature_generators_preserve_schema() {
    use sgg::featgen::kde::KdeFeatureGen;
    use sgg::featgen::random::RandomFeatureGen;
    use sgg::featgen::table::{Column, FeatureTable};
    use sgg::featgen::FeatureGenerator;
    check("featgen schema", 15, |rng| {
        let n = 50 + rng.below_usize(500);
        let k = 2 + rng.below(6) as u32;
        let t = FeatureTable::new(vec![
            Column::continuous("a", (0..n).map(|_| rng.normal()).collect()),
            Column::categorical("b", (0..n).map(|_| rng.below(k as u64) as u32).collect()),
        ])
        .map_err(|e| e.to_string())?;
        for (name, g) in [
            ("kde", Box::new(KdeFeatureGen::fit(&t)) as Box<dyn FeatureGenerator>),
            ("random", Box::new(RandomFeatureGen::fit(&t))),
        ] {
            let m = 10 + rng.below_usize(200);
            let s = g.sample(m, rng.next_u64()).map_err(|e| e.to_string())?;
            prop_assert!(s.n_rows() == m, "{name} rows");
            prop_assert!(s.n_cols() == 2, "{name} cols");
            let (codes, card) = s.columns[1].as_categorical();
            prop_assert!(card <= k, "{name} cardinality grew");
            prop_assert!(codes.iter().all(|&c| c < k), "{name} code out of range");
        }
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip_fuzz() {
    use sgg::util::json::Json;
    fn random_json(rng: &mut Pcg64, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.bool(0.5)),
            2 => Json::Num((rng.next_u64() % 100_000) as f64 / 8.0),
            3 => {
                let len = rng.below_usize(12);
                Json::Str(
                    (0..len)
                        .map(|_| char::from_u32(32 + rng.below(90) as u32).unwrap())
                        .collect(),
                )
            }
            4 => Json::Arr((0..rng.below_usize(5)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => {
                let mut m = std::collections::BTreeMap::new();
                for i in 0..rng.below_usize(5) {
                    m.insert(format!("k{i}"), random_json(rng, depth - 1));
                }
                Json::Obj(m)
            }
        }
    }
    check("json roundtrip", 100, |rng| {
        let v = random_json(rng, 3);
        let s = v.to_string();
        let back = Json::parse(&s).map_err(|e| format!("{e} in `{s}`"))?;
        prop_assert!(back == v, "roundtrip mismatch: {s}");
        Ok(())
    });
}

#[test]
fn prop_sggedge2_roundtrip_preserves_the_edge_multiset() {
    use sgg::graph::io;
    check("sggedge2 roundtrip", 25, |rng| {
        // occasionally stress the widest ids the format must carry
        // (10-byte varints); otherwise a broad random id range
        let spec = if rng.bool(0.25) {
            PartiteSpec::square(u64::MAX)
        } else {
            PartiteSpec::bipartite(1 + rng.below(1 << 40), 1 + rng.below(1 << 40))
        };
        let mut e = EdgeList::new(spec);
        for _ in 0..rng.below(2_000) {
            e.push(rng.below(spec.n_src), rng.below(spec.n_dst));
        }
        if spec.n_src == u64::MAX {
            e.push(u64::MAX - 1, u64::MAX - 1);
            e.push(0, u64::MAX - 1);
        }
        let path = std::env::temp_dir().join(format!(
            "sgg_prop_e2_{}_{:016x}.sgg",
            std::process::id(),
            rng.next_u64()
        ));
        let res = (|| -> Result<(), String> {
            io::write_binary2(&path, &e).map_err(|x| x.to_string())?;
            let back = io::read_binary(&path).map_err(|x| x.to_string())?;
            prop_assert!(back.len() == e.len(), "count {} != {}", back.len(), e.len());
            prop_assert!(
                io::decoded_checksum(&back) == io::decoded_checksum(&e),
                "edge multiset changed in the round trip"
            );
            // the decoded stream is sorted by (src, dst) — the format's
            // within-chunk ordering guarantee
            let pairs: Vec<_> = back.iter().collect();
            for w in pairs.windows(2) {
                prop_assert!(w[0] <= w[1], "decoded stream not sorted: {:?}", w);
            }
            Ok(())
        })();
        std::fs::remove_file(&path).ok();
        res
    });
}

#[test]
fn prop_builtin_backends_are_deterministic_and_worker_invariant() {
    use sgg::graph::io;
    check("backend determinism", 5, |rng| {
        // a small random source graph to fit the data-driven backends on
        let n = 64 + rng.below(64);
        let mut source = EdgeList::new(PartiteSpec::square(n));
        for _ in 0..1_500 {
            source.push(rng.below(n), rng.below(n));
        }
        let theta = random_theta(rng);
        let backends: Vec<Box<dyn StructureGenerator>> = vec![
            Box::new(KroneckerGen::new(theta, PartiteSpec::square(256), 3_000)),
            Box::new(sgg::structgen::erdos_renyi::ErdosRenyi::fit(&source)),
            Box::new(sgg::structgen::sbm::DcSbm::fit(&source, 4)),
            Box::new(sgg::structgen::trilliong::TrillionG::fit(&source)),
        ];
        let seed = rng.next_u64();
        let workers = 2 + rng.below_usize(4);
        for gen in &backends {
            let (spec, base_edges) = gen.base();
            let edges = base_edges.clamp(500, 3_000);
            // the batched hot path must be reproducible call over call
            let a = gen
                .generate_sized(spec.n_src, spec.n_dst, edges, seed)
                .map_err(|e| e.to_string())?;
            let b = gen
                .generate_sized(spec.n_src, spec.n_dst, edges, seed)
                .map_err(|e| e.to_string())?;
            prop_assert!(a.src == b.src && a.dst == b.dst, "{}: rerun differs", gen.name());
            // chunked execution folds to the same edge multiset at any
            // worker count (decoded checksum is order-invariant)
            let mut fold = |w: usize| -> Result<(u64, u64), String> {
                let cfg =
                    ChunkConfig { prefix_levels: 2, workers: w, ..ChunkConfig::default() };
                let (mut sum, mut count) = (0u64, 0u64);
                gen.generate_into(spec.n_src, spec.n_dst, edges, seed, cfg, &mut |c| {
                    sum = sum.wrapping_add(io::decoded_checksum(&c.edges));
                    count += c.edges.len() as u64;
                    Ok(())
                })
                .map_err(|e| e.to_string())?;
                Ok((sum, count))
            };
            let (s1, c1) = fold(1)?;
            let (sk, ck) = fold(workers)?;
            prop_assert!(
                c1 == edges && ck == edges,
                "{}: chunked counts {c1}/{ck} != {edges}",
                gen.name()
            );
            prop_assert!(s1 == sk, "{}: worker count changed the edge multiset", gen.name());
        }
        Ok(())
    });
}

#[test]
fn prop_density_preserved_across_scales() {
    check("density preservation", 20, |rng| {
        let spec = PartiteSpec::bipartite(1 + rng.below(10_000), 1 + rng.below(10_000));
        let e = 1 + rng.below(1_000_000);
        let k = 1 + rng.below(8);
        let d0 = spec.density(e);
        let d1 = spec.scaled(k).density(spec.density_preserving_edges(e, k));
        prop_assert!((d0 - d1).abs() < 1e-12 * d0.max(1.0), "{d0} vs {d1}");
        Ok(())
    });
}
