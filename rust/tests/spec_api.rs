//! Integration tests for the declarative scenario API: spec file →
//! registry-resolved components → fit → generate, through both sinks.

use sgg::pipeline::{run_scenario, Registries, ScenarioSpec, SinkOutput, SinkSpec};

fn write_spec(name: &str, text: &str) -> std::path::PathBuf {
    let path = std::env::temp_dir().join(format!("sgg_spec_{}_{name}.toml", std::process::id()));
    std::fs::write(&path, text).unwrap();
    path
}

#[test]
fn spec_file_fit_generate_roundtrip() {
    let path = write_spec(
        "roundtrip",
        r#"
        name = "roundtrip"
        dataset = "travel-insurance"
        seed = 9

        [structure]
        backend = "erdos-renyi"

        [edge_features]
        backend = "random"

        [aligner]
        backend = "random"
        "#,
    );
    let spec = ScenarioSpec::from_file(&path).unwrap();
    let ds = sgg::datasets::load(&spec.dataset, spec.dataset_seed).unwrap();
    let out = run_scenario(&spec).unwrap();
    let synth = out.into_dataset().unwrap();
    assert_eq!(synth.edges.len(), ds.edges.len());
    assert_eq!(synth.edge_features.n_rows(), ds.edges.len());
    assert_eq!(synth.edge_features.n_cols(), ds.edge_features.n_cols());
    std::fs::remove_file(path).ok();
}

#[test]
fn checked_in_fraud_spec_generates_node_and_edge_features() {
    // the repo's conformance spec must stay runnable end to end
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("../scenarios/fraud.toml");
    let mut spec = ScenarioSpec::from_file(&path).unwrap();
    assert_eq!(spec.dataset, "ieee-fraud");
    // shrink to scale 1 to keep CI fast; components stay as checked in
    spec.size = sgg::pipeline::SizeSpec::Scale(1);
    let ds = sgg::datasets::load(&spec.dataset, spec.dataset_seed).unwrap();
    let src_nf_cols = ds.node_features.as_ref().expect("ieee-fraud has node features").n_cols();
    let synth = run_scenario(&spec).unwrap().into_dataset().unwrap();
    assert_eq!(synth.edge_features.n_rows(), synth.edges.len());
    let nf = synth.node_features.expect("spec requests node features");
    assert_eq!(nf.n_rows(), synth.edges.spec.n_src as usize);
    assert_eq!(nf.n_cols(), src_nf_cols);
}

#[test]
fn shards_sink_streams_through_unified_path() {
    let dir = std::env::temp_dir().join(format!("sgg_spec_shards_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let path = write_spec(
        "shards",
        &format!(
            r#"
            dataset = "travel-insurance"
            seed = 4

            [aligner]
            backend = "random"

            [edge_features]
            backend = "random"

            [sink]
            kind = "shards"
            dir = "{}"
            prefix_levels = 2
            workers = 2
            queue_capacity = 2
            "#,
            dir.display()
        ),
    );
    let spec = ScenarioSpec::from_file(&path).unwrap();
    assert!(matches!(spec.sink, SinkSpec::Shards { .. }));
    let ds = sgg::datasets::load(&spec.dataset, spec.dataset_seed).unwrap();
    match run_scenario(&spec).unwrap() {
        SinkOutput::Streamed(report) => {
            assert_eq!(report.edges_written, ds.edges.len() as u64);
            assert!(report.shards >= 1);
            assert!(report.peak_buffer_bytes > 0);
            let back = sgg::pipeline::orchestrator::read_shards(&dir).unwrap();
            assert_eq!(back.len(), ds.edges.len());
            assert!(back.validate().is_ok());
        }
        SinkOutput::Dataset(_) => panic!("shards sink returned a dataset"),
    }
    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_file(path).ok();
}

#[test]
fn unknown_component_is_helpful_config_error() {
    let path = write_spec(
        "unknown",
        r#"
        dataset = "travel-insurance"

        [structure]
        backend = "quantum-annealer"
        "#,
    );
    let spec = ScenarioSpec::from_file(&path).unwrap();
    let err = run_scenario(&spec).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("quantum-annealer"), "{msg}");
    // the error lists what IS registered
    for known in ["kronecker", "erdos-renyi", "sbm", "trilliong"] {
        assert!(msg.contains(known), "missing `{known}` in: {msg}");
    }
    std::fs::remove_file(path).ok();
}

#[test]
fn custom_backend_registers_and_resolves() {
    // the registry is open: a downstream crate can plug a backend in
    fn tiny(
        ctx: &sgg::structgen::StructureFitContext<'_>,
    ) -> sgg::Result<Box<dyn sgg::structgen::StructureGenerator>> {
        Ok(Box::new(sgg::structgen::erdos_renyi::ErdosRenyi::fit(ctx.edges)))
    }
    let mut regs = Registries::builtin();
    regs.structure.register("tiny-er", tiny);
    let ds = sgg::datasets::load("travel-insurance", 2).unwrap();
    let fitted = sgg::pipeline::Pipeline::builder()
        .structure("tiny-er")
        .edge_features("random")
        .aligner("random")
        .fit_with(&ds, &regs)
        .unwrap();
    assert_eq!(fitted.component_names().0, "random"); // ER's display name
    assert_eq!(fitted.generate(1, 1).unwrap().edges.len(), ds.edges.len());
}
