//! Fault-path integration tests: every injected failure mode must
//! surface as a single clean `Error` — no hang, no partial out-of-order
//! writer output, no stale temp files — and every transient fault must
//! be absorbed by retries with bit-identical output.

use sgg::graph::{io, EdgeList, PartiteSpec};
use sgg::pipeline::{
    ChunkPlan, FaultPlan, FaultSink, ParallelChunkRunner, RetryPolicy, RetryingSink,
    ShardSink, Sink,
};
use sgg::structgen::chunked::ChunkConfig;
use std::path::{Path, PathBuf};

fn tmp(name: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("sgg_faultit_{}_{name}", std::process::id()));
    std::fs::remove_dir_all(&p).ok();
    std::fs::create_dir_all(&p).unwrap();
    p
}

/// Deterministic test plan: chunk `i` holds `per` edges derived from
/// `i` alone, so any two runs (or any recovered run) produce identical
/// chunks. Optionally panics persistently at one index.
struct Plan {
    n: usize,
    per: usize,
    panic_at: Option<usize>,
}

impl ChunkPlan for Plan {
    fn n_chunks(&self) -> usize {
        self.n
    }

    fn sample(&self, index: usize) -> sgg::Result<EdgeList> {
        if Some(index) == self.panic_at {
            panic!("plan panics at chunk {index}");
        }
        let mut e = EdgeList::new(PartiteSpec::square(64));
        for j in 0..self.per as u64 {
            e.push((index as u64 * 31 + j) % 64, (index as u64 * 17 + j * 7) % 64);
        }
        Ok(e)
    }
}

/// Shard filenames under `dir`, sorted.
fn shard_names(dir: &Path) -> Vec<String> {
    let mut names: Vec<String> = std::fs::read_dir(dir)
        .unwrap()
        .map(|e| e.unwrap().file_name().into_string().unwrap())
        .collect();
    names.sort();
    names
}

/// A mid-pool worker panic (with retries exhausted) surfaces as one
/// clean `Error::Worker`, the run terminates (no hang — the test
/// finishing proves the pool drained), and the in-order writer emitted
/// only the prefix before the failed chunk.
fn worker_panic_mid_pool(_dir: &Path) {
    let plan = Plan { n: 12, per: 50, panic_at: Some(6) };
    let cfg = ChunkConfig {
        workers: 4,
        queue_capacity: 2,
        retry: RetryPolicy::none(),
        ..ChunkConfig::default()
    };
    let runner = ParallelChunkRunner::from_config(cfg);
    let mut seen: Vec<usize> = Vec::new();
    let err = runner
        .run(&plan, &mut |c| {
            seen.push(c.index);
            Ok(())
        })
        .unwrap_err();
    assert!(
        matches!(err, sgg::Error::Worker(_)),
        "expected a worker error, got: {err}"
    );
    assert!(err.to_string().contains("panic"), "{err}");
    // the sink saw a strictly in-order prefix of 0..6, nothing after
    assert_eq!(seen, (0..seen.len()).collect::<Vec<_>>());
    assert!(seen.len() <= 6, "chunks past the panic leaked: {seen:?}");
}

/// A fatal shard-write error mid-stream aborts the run with the sink's
/// error, and the output directory holds exactly the consecutive
/// in-order prefix — no gaps, no out-of-order shards, no temp files.
fn sink_error_mid_stream(dir: &Path) {
    let plan = Plan { n: 10, per: 40, panic_at: None };
    let cfg = ChunkConfig { workers: 4, queue_capacity: 2, ..ChunkConfig::default() };
    let mut sink = ShardSink::new(dir, cfg).unwrap();
    let mut faulted = FaultSink::new(&mut sink, FaultPlan::fatal_at(3));
    let runner = ParallelChunkRunner::from_config(cfg);
    let err = runner.run(&plan, &mut |c| faulted.edges(c)).unwrap_err();
    assert!(err.to_string().contains("fatal"), "{err}");
    assert_eq!(
        shard_names(dir),
        vec!["shard-00000.sgg", "shard-00001.sgg", "shard-00002.sgg"]
    );
}

/// A shard truncated after open (header still consistent at open time)
/// fails the read with a single context-carrying error: the shard path
/// and byte offset are in the message.
fn truncated_shard_read(dir: &Path) {
    let mut edges = EdgeList::new(PartiteSpec::square(32));
    for i in 0..100u64 {
        edges.push(i % 32, (i * 3) % 32);
    }
    io::write_binary(&dir.join("shard-00000.sgg"), &edges).unwrap();
    io::write_binary(&dir.join("shard-00001.sgg"), &edges).unwrap();
    let reader = io::ShardReader::open(dir).unwrap();
    // truncate shard 1's body behind the already-validated reader
    let victim = dir.join("shard-00001.sgg");
    let bytes = std::fs::read(&victim).unwrap();
    std::fs::write(&victim, &bytes[..bytes.len() - 24]).unwrap();
    assert!(reader.read(0).is_ok());
    let err = reader.read(1).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("shard io error"), "{msg}");
    assert!(msg.contains("shard-00001.sgg"), "{msg}");
    // truncation is corruption, not a transient blip: no retry applies
    assert!(!err.is_transient(), "{msg}");
    // at open time the same truncation is caught by size validation
    let err = io::ShardReader::open(dir).unwrap_err();
    assert!(err.to_string().contains("bytes"), "{err}");
}

/// Every SGGEDGE2 corruption mode — truncation, payload bit-flips, an
/// unknown format version, forged header counts — fails the read with a
/// single `Error::ShardIo` carrying the shard path and a byte offset,
/// never a panic, a hang, or a silently wrong edge list.
fn sggedge2_corruption_paths(dir: &Path) {
    let mut edges = EdgeList::new(PartiteSpec::square(64));
    for i in 0..200u64 {
        edges.push((i * 7) % 64, (i * 13) % 64);
    }
    let path = dir.join("shard-00000.sgg");
    io::write_shard(&path, &edges, io::ShardFormat::Edge2).unwrap();
    let good = std::fs::read(&path).unwrap();

    // (case, corrupted bytes, message substring the error must carry)
    let truncated = good[..good.len() - 5].to_vec();
    let mut flipped = good.clone();
    let last = flipped.len() - 1;
    flipped[last] ^= 0x01;
    let mut future_version = good.clone();
    future_version[7] = b'9';
    let mut forged_count = good.clone();
    forged_count[25..33].copy_from_slice(&u64::MAX.to_le_bytes());
    let mut forged_payload_len = good.clone();
    forged_payload_len[33..41].copy_from_slice(&u64::MAX.to_le_bytes());
    let cases: &[(&str, &[u8], &str)] = &[
        ("truncated file", &truncated, "bytes"),
        ("flipped payload bit", &flipped, "checksum mismatch"),
        ("unknown version byte", &future_version, "unsupported shard format version"),
        ("forged edge count", &forged_count, "edge count"),
        ("forged payload length", &forged_payload_len, "overflows"),
    ];
    for (name, bytes, needle) in cases {
        std::fs::write(&path, bytes).unwrap();
        let err = io::read_binary(&path).unwrap_err();
        match &err {
            sgg::Error::ShardIo { path: p, .. } => {
                assert!(
                    p.to_string_lossy().contains("shard-00000.sgg"),
                    "{name}: error lost the shard path: {err}"
                );
            }
            other => panic!("{name}: expected Error::ShardIo, got: {other}"),
        }
        let msg = err.to_string();
        assert!(msg.contains(needle), "{name}: `{needle}` not in `{msg}`");
        assert!(msg.contains("at byte"), "{name}: no byte offset in `{msg}`");
        // corruption is never retried as a transient blip
        assert!(!err.is_transient(), "{name}: {msg}");
        // the header-only path rejects header-level corruption the same
        // way instead of trusting a poisoned edge count
        if *name != "flipped payload bit" {
            assert!(io::read_binary_header(&path).is_err(), "{name}: header path accepted it");
        }
    }

    // restoring the original bytes restores a clean decode
    std::fs::write(&path, &good).unwrap();
    let back = io::read_binary(&path).unwrap();
    assert_eq!(back.len(), edges.len());
    assert_eq!(io::decoded_checksum(&back), io::decoded_checksum(&edges));
}

/// A full transient fault schedule — sampling faults, sink faults, one
/// injected worker panic — recovers via retries to shards byte-identical
/// to a fault-free run.
fn transient_faults_recover_byte_identically(dir: &Path) {
    let plan = Plan { n: 8, per: 60, panic_at: None };
    let clean_dir = dir.join("clean");
    let fault_dir = dir.join("faulted");
    for (out, faults) in [
        (&clean_dir, None),
        (&fault_dir, Some(FaultPlan::transient(23))),
    ] {
        let cfg = ChunkConfig {
            workers: 3,
            queue_capacity: 2,
            faults,
            ..ChunkConfig::default()
        };
        let mut sink = ShardSink::new(out, cfg).unwrap();
        let runner = ParallelChunkRunner::from_config(cfg);
        match faults {
            Some(plan_) => {
                let mut faulted = FaultSink::new(&mut sink, plan_);
                let mut retrying = RetryingSink::new(&mut faulted, cfg.retry);
                runner.run(&plan, &mut |c| retrying.edges(c)).unwrap();
            }
            None => {
                runner.run(&plan, &mut |c| sink.edges(c)).unwrap();
            }
        }
        sink.finish().unwrap();
    }
    let names = shard_names(&clean_dir);
    assert_eq!(names, shard_names(&fault_dir));
    assert!(!names.is_empty());
    for n in &names {
        let a = std::fs::read(clean_dir.join(n)).unwrap();
        let b = std::fs::read(fault_dir.join(n)).unwrap();
        assert_eq!(a, b, "shard {n} differs under faults");
    }
}

/// An interrupted scenario run resumed with `RunOptions::resume`
/// produces a directory byte-identical to an uninterrupted run, at
/// multiple worker counts — through the public scenario API.
fn interrupted_scenario_resumes_byte_identically(dir: &Path) {
    use sgg::pipeline::{run_scenario_opts, Registries, RunOptions, ScenarioSpec, SinkSpec};
    let spec_text = r#"
name = "resume-it"
dataset = "travel-insurance"
seed = 31

[structure]
backend = "erdos-renyi"

[edge_features]
backend = "random"

[aligner]
backend = "random"

[sink]
kind = "shards"
"#;
    for workers in [1usize, 4] {
        let mut spec = ScenarioSpec::parse(spec_text).unwrap();
        spec.workers = workers;
        let full_dir = dir.join(format!("full{workers}"));
        let broken_dir = dir.join(format!("broken{workers}"));
        let with_dir = |spec: &mut ScenarioSpec, d: &Path| match &mut spec.sink {
            SinkSpec::Shards { dir, chunks } => {
                *dir = d.to_path_buf();
                // parse time resolved the inherited worker count already;
                // re-zero so the override above takes effect
                chunks.workers = 0;
            }
            other => panic!("expected shard sink, got {other:?}"),
        };
        // reference: uninterrupted
        with_dir(&mut spec, &full_dir);
        run_scenario_opts(&spec, &Registries::builtin(), RunOptions::default()).unwrap();
        // interrupted at chunk 1, then resumed
        with_dir(&mut spec, &broken_dir);
        let crash = RunOptions { faults: Some(FaultPlan::fatal_at(1)), ..Default::default() };
        run_scenario_opts(&spec, &Registries::builtin(), crash)
            .expect_err("fatal fault must interrupt the run");
        let resume = RunOptions { resume: true, ..Default::default() };
        run_scenario_opts(&spec, &Registries::builtin(), resume).unwrap();
        let names = shard_names(&full_dir);
        assert_eq!(names, shard_names(&broken_dir), "workers={workers}");
        for n in &names {
            let a = std::fs::read(full_dir.join(n)).unwrap();
            let b = std::fs::read(broken_dir.join(n)).unwrap();
            assert_eq!(a, b, "shard {n} differs after resume (workers={workers})");
        }
    }
}

#[test]
fn fault_paths_table() {
    let cases: &[(&str, fn(&Path))] = &[
        ("worker_panic_mid_pool", worker_panic_mid_pool),
        ("sink_error_mid_stream", sink_error_mid_stream),
        ("truncated_shard_read", truncated_shard_read),
        ("sggedge2_corruption_paths", sggedge2_corruption_paths),
        (
            "transient_faults_recover_byte_identically",
            transient_faults_recover_byte_identically,
        ),
        (
            "interrupted_scenario_resumes_byte_identically",
            interrupted_scenario_resumes_byte_identically,
        ),
    ];
    for (name, case) in cases {
        let dir = tmp(name);
        case(&dir);
        std::fs::remove_dir_all(&dir).ok();
    }
}
