//! Integration tests across modules: dataset → pipeline → metrics, the
//! streaming orchestrator, and CLI-level component parsing.

use sgg::aligner::AlignKind;
use sgg::featgen::FeatKind;
use sgg::metrics;
use sgg::pipeline::Pipeline;
use sgg::structgen::StructKind;

fn small(name: &str) -> sgg::datasets::Dataset {
    let mut ds = sgg::datasets::load(name, 3).unwrap();
    // subsample for test speed
    let keep: Vec<usize> = (0..ds.edges.len()).step_by(4).collect();
    ds.edge_features = ds.edge_features.gather(&keep);
    let mut edges = sgg::graph::EdgeList::new(ds.edges.spec);
    for &i in &keep {
        edges.push(ds.edges.src[i], ds.edges.dst[i]);
    }
    ds.edges = edges;
    ds
}

#[test]
fn pipeline_reproduces_table2_ordering() {
    // the paper's headline: fitted pipeline beats the random baseline on
    // degree-dist and joint degree-feature metrics
    let ds = small("tabformer");
    let ours = Pipeline::builder().fit(&ds).unwrap().generate(1, 5).unwrap();
    let rand = Pipeline::builder()
        .structure("erdos-renyi")
        .edge_features("random")
        .aligner("random")
        .fit(&ds)
        .unwrap()
        .generate(1, 5)
        .unwrap();
    let r_ours = metrics::evaluate(&ds.edges, &ds.edge_features, &ours.edges, &ours.edge_features);
    let r_rand = metrics::evaluate(&ds.edges, &ds.edge_features, &rand.edges, &rand.edge_features);
    assert!(
        r_ours.degree_dist > r_rand.degree_dist,
        "degree: ours={} rand={}",
        r_ours.degree_dist,
        r_rand.degree_dist
    );
    assert!(
        r_ours.feature_corr > r_rand.feature_corr,
        "featcorr: ours={} rand={}",
        r_ours.feature_corr,
        r_rand.feature_corr
    );
    assert!(
        r_ours.degree_feat_dist < r_rand.degree_feat_dist,
        "joint: ours={} rand={}",
        r_ours.degree_feat_dist,
        r_rand.degree_feat_dist
    );
}

#[test]
fn generated_graph_is_valid_at_scale() {
    let ds = small("travel-insurance");
    let fitted = Pipeline::builder().fit(&ds).unwrap();
    for scale in [1u64, 2, 3] {
        let synth = fitted.generate(scale, scale).unwrap();
        assert!(synth.edges.validate().is_ok());
        assert_eq!(synth.edges.spec.n_src, ds.edges.spec.n_src * scale);
        assert_eq!(synth.edges.len() as u64, ds.edges.len() as u64 * scale * scale);
        assert_eq!(synth.edge_features.n_rows(), synth.edges.len());
    }
}

#[test]
fn enum_kinds_lower_onto_registry_names() {
    // the closed enums survive as CLI parsing helpers; their
    // registry_name() strings must keep resolving through the builder
    // (this replaces the removed `PipelineConfig` shim test)
    let ds = small("tabformer");
    let fitted = Pipeline::builder()
        .structure(StructKind::Random.registry_name())
        .edge_features(FeatKind::Random.registry_name())
        .aligner(AlignKind::Random.registry_name())
        .fit(&ds)
        .unwrap();
    let synth = fitted.generate(1, 5).unwrap();
    assert_eq!(synth.edges.len(), ds.edges.len());
    let (s, f, a) = fitted.component_names();
    assert_eq!((s.as_str(), f.as_str(), a.as_str()), ("random", "random", "random"));
}

#[test]
fn streaming_pipeline_bounded_and_complete() {
    use sgg::pipeline::orchestrator::{read_shards, stream_to_shards};
    use sgg::structgen::chunked::ChunkConfig;
    let ds = small("ieee-fraud");
    let gen = sgg::structgen::fit::fit_kronecker(&ds.edges);
    let dir = std::env::temp_dir().join(format!("sgg_it_stream_{}", std::process::id()));
    let cfg = ChunkConfig { prefix_levels: 2, workers: 4, queue_capacity: 2, ..ChunkConfig::default() };
    let report = stream_to_shards(
        &gen,
        ds.edges.spec.n_src,
        ds.edges.spec.n_dst,
        50_000,
        3,
        cfg,
        &dir,
    )
    .unwrap();
    assert_eq!(report.edges_written, 50_000);
    let back = read_shards(&dir).unwrap();
    assert_eq!(back.len(), 50_000);
    assert!(back.validate().is_ok());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn struct_kind_parsing_matches_cli_contract() {
    assert_eq!("ours".parse::<StructKind>().unwrap(), StructKind::Kronecker);
    assert_eq!("graphworld".parse::<StructKind>().unwrap(), StructKind::Sbm);
    assert_eq!("er".parse::<StructKind>().unwrap(), StructKind::Random);
    assert!("bogus".parse::<StructKind>().is_err());
    assert_eq!("gan".parse::<FeatKind>().unwrap(), FeatKind::Gan);
    assert_eq!("learned".parse::<AlignKind>().unwrap(), AlignKind::Learned);
}

#[test]
fn experiment_registry_has_every_table_and_figure() {
    // every table (2-10) and figure (2,4,5,6,7,8) of the paper's
    // evaluation maps to a harness
    for id in [
        "table2", "table3", "table4", "table5", "table6", "table7", "table8",
        "table9", "table10", "figure2", "figure4", "figure5", "figure6",
        "figure7", "figure8",
    ] {
        assert!(sgg::experiments::ALL.contains(&id), "missing {id}");
    }
}

#[test]
fn graph_io_roundtrip_through_dataset() {
    let ds = small("paysim");
    let path = std::env::temp_dir().join(format!("sgg_it_io_{}.sgg", std::process::id()));
    sgg::graph::io::write_binary(&path, &ds.edges).unwrap();
    let back = sgg::graph::io::read_binary(&path).unwrap();
    assert_eq!(back.src, ds.edges.src);
    assert_eq!(back.spec, ds.edges.spec);
    std::fs::remove_file(path).ok();
}
