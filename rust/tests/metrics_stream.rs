//! Streamed-evaluation exactness properties: chunked + merged metric
//! accumulators reproduce the in-memory `metrics::evaluate` scores bit
//! for bit, merges are associative/commutative where claimed, and
//! `evaluate_shards` (the `sgg eval --shards` path) is invariant to
//! worker count and shard count.

use sgg::graph::{io, EdgeList, PartiteSpec};
use sgg::metrics::degree::{
    dcc_profiles, degree_dist_score, degree_dist_score_profiles, DegreeAccumulator,
};
use sgg::metrics::stream::{evaluate_shards, profile_shards, DCC_SAMPLES};
use sgg::metrics::{DegreeProfile, Evaluator, FeatureProfile, MetricAccumulator};
use sgg::featgen::table::{Column, FeatureTable};
use sgg::structgen::chunked::ChunkConfig;
use sgg::structgen::kronecker::KroneckerGen;
use sgg::structgen::theta::ThetaS;
use sgg::util::proptest::check;
use sgg::util::rng::Pcg64;
use std::path::PathBuf;

fn random_graph(rng: &mut Pcg64, n: u64, m: usize) -> EdgeList {
    let mut e = EdgeList::new(PartiteSpec::square(n));
    for _ in 0..m {
        e.push(rng.below(n), rng.below(n));
    }
    e
}

fn random_feats(rng: &mut Pcg64, rows: usize) -> FeatureTable {
    let vals: Vec<f64> = (0..rows).map(|_| rng.normal()).collect();
    let codes: Vec<u32> = (0..rows).map(|_| rng.below(4) as u32).collect();
    FeatureTable::new(vec![
        Column::continuous("v", vals),
        Column::categorical("c", codes),
    ])
    .unwrap()
}

/// Random cut points splitting `0..len` into 1..=5 non-empty ranges.
fn random_cuts(rng: &mut Pcg64, len: usize) -> Vec<usize> {
    let pieces = 1 + rng.below(5) as usize;
    let mut cuts: Vec<usize> = (0..pieces - 1)
        .map(|_| rng.below(len.max(1) as u64) as usize)
        .collect();
    cuts.push(0);
    cuts.push(len);
    cuts.sort_unstable();
    cuts.dedup();
    cuts
}

fn slice_edges(e: &EdgeList, lo: usize, hi: usize) -> EdgeList {
    let mut out = EdgeList::new(e.spec);
    for i in lo..hi {
        out.push(e.src[i], e.dst[i]);
    }
    out
}

fn tmp_dir(name: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("sgg_msint_{}_{name}", std::process::id()));
    std::fs::remove_dir_all(&p).ok();
    std::fs::create_dir_all(&p).unwrap();
    p
}

#[test]
fn prop_chunked_merged_degree_profile_is_bit_exact() {
    check("chunked+merged degree profile == one-pass", 40, |rng| {
        let n = 32 + rng.below(512);
        let m = 200 + rng.below(3_000) as usize;
        let g = random_graph(rng, n, m);
        let whole = DegreeProfile::of(&g);
        let cuts = random_cuts(rng, g.len());
        let mut merged = DegreeAccumulator::new();
        for w in cuts.windows(2) {
            let mut part = DegreeAccumulator::new();
            part.observe_edges(&slice_edges(&g, w[0], w[1]));
            merged.merge(part);
        }
        if merged.clone().finalize() != whole {
            return Err("merged profile != one-pass profile".into());
        }
        // commutativity: merging the partials in reverse is identical
        let mut rev = DegreeAccumulator::new();
        for w in cuts.windows(2).rev() {
            let mut part = DegreeAccumulator::new();
            part.observe_edges(&slice_edges(&g, w[0], w[1]));
            rev.merge(part);
        }
        if rev.finalize() != whole {
            return Err("reverse-merged profile != one-pass profile".into());
        }
        Ok(())
    });
}

#[test]
fn prop_streamed_quality_report_matches_evaluate_bit_for_bit() {
    check("streamed QualityReport == metrics::evaluate", 15, |rng| {
        let n = 64 + rng.below(256);
        let m = 500 + rng.below(2_000) as usize;
        let orig_e = random_graph(rng, n, m);
        let orig_f = random_feats(rng, m);
        let synth_e = random_graph(rng, n, m);
        let synth_f = random_feats(rng, m);
        let direct = sgg::metrics::evaluate(&orig_e, &orig_f, &synth_e, &synth_f);

        // streamed path: synth edges arrive in random chunks, features
        // in row blocks; orig is profiled once by the Evaluator
        let ev = Evaluator::new(&orig_e, &orig_f);
        let cuts = random_cuts(rng, synth_e.len());
        let mut deg = DegreeAccumulator::new();
        for w in cuts.windows(2) {
            let mut part = DegreeAccumulator::new();
            part.observe_edges(&slice_edges(&synth_e, w[0], w[1]));
            deg.merge(part);
        }
        let synth_prof = deg.finalize();
        let streamed_degree = ev.degree_dist(&synth_prof);
        if streamed_degree.to_bits() != direct.degree_dist.to_bits() {
            return Err(format!(
                "degree_dist streamed {streamed_degree} != direct {}",
                direct.degree_dist
            ));
        }
        // feature metrics via the same shared-profile engine
        let full = ev.score(&synth_e, &synth_f);
        if full.feature_corr.to_bits() != direct.feature_corr.to_bits()
            || full.degree_feat_dist.to_bits() != direct.degree_feat_dist.to_bits()
        {
            return Err("Evaluator::score != metrics::evaluate".into());
        }
        Ok(())
    });
}

#[test]
fn prop_assoc_profile_sequential_chunking_is_bit_exact() {
    check("sequential feature chunking == one block", 20, |rng| {
        let rows = 300 + rng.below(1_500) as usize;
        let t = random_feats(rng, rows);
        let whole = FeatureProfile::of(&t);
        let cuts = random_cuts(rng, rows);
        let mut acc = sgg::metrics::featcorr::AssocAccumulator::new();
        for w in cuts.windows(2) {
            let idx: Vec<usize> = (w[0]..w[1]).collect();
            acc.observe_features(&t.gather(&idx));
        }
        let chunked = acc.finalize();
        for (a, b) in whole.matrix().iter().zip(chunked.matrix()) {
            if a.to_bits() != b.to_bits() {
                return Err(format!("matrix entry {a} != {b}"));
            }
        }
        Ok(())
    });
}

#[test]
fn shard_eval_invariant_to_workers_and_shard_count() {
    let mut rng = Pcg64::new(0xe5a1);
    let orig = random_graph(&mut rng, 300, 9_000);
    let synth = random_graph(&mut rng, 300, 9_000);
    let orig_prof = DegreeProfile::of(&orig);
    let expected = degree_dist_score(&orig, &synth);
    let expected_dcc = dcc_profiles(&orig_prof, &DegreeProfile::of(&synth), DCC_SAMPLES);
    for shards in [1usize, 2, 5, 11] {
        let dir = tmp_dir(&format!("inv{shards}"));
        let per = synth.len().div_ceil(shards);
        for (i, start) in (0..synth.len()).step_by(per).enumerate() {
            let chunk = slice_edges(&synth, start, (start + per).min(synth.len()));
            io::write_binary(&dir.join(format!("shard-{i:05}.sgg")), &chunk).unwrap();
        }
        for workers in [1usize, 3, 8] {
            let r = evaluate_shards(&dir, &orig_prof, workers).unwrap();
            assert_eq!(
                r.degree_dist.to_bits(),
                expected.to_bits(),
                "degree_dist drifted at shards={shards} workers={workers}"
            );
            assert_eq!(
                r.dcc.to_bits(),
                expected_dcc.to_bits(),
                "dcc drifted at shards={shards} workers={workers}"
            );
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn shard_eval_reproduces_in_memory_scores_on_shardsink_output() {
    // the acceptance path: generate through the real ShardSink, then
    // evaluate the directory without materializing it
    let nodes = 1u64 << 10;
    let edges = 30_000u64;
    let gen = KroneckerGen::new(ThetaS::rmat_default(), PartiteSpec::square(nodes), edges);
    let dir = tmp_dir("sink");
    let cfg = ChunkConfig { prefix_levels: 2, workers: 3, queue_capacity: 2, ..ChunkConfig::default() };
    sgg::pipeline::orchestrator::stream_to_shards(&gen, nodes, nodes, edges, 5, cfg, &dir)
        .unwrap();
    // reference: a different seed of the same generator, in memory
    let orig = {
        use sgg::structgen::StructureGenerator;
        gen.generate_sized(nodes, nodes, edges, 9).unwrap()
    };
    let orig_prof = DegreeProfile::of(&orig);
    // in-memory: materialize all shards and score
    let whole = sgg::pipeline::orchestrator::read_shards(&dir).unwrap();
    let expected = degree_dist_score_profiles(&orig_prof, &DegreeProfile::of(&whole));
    for workers in [1usize, 4] {
        let r = evaluate_shards(&dir, &orig_prof, workers).unwrap();
        assert_eq!(r.degree_dist.to_bits(), expected.to_bits(), "workers={workers}");
        assert_eq!(r.edges, edges);
        // resident bound: the largest shard is a fraction of the graph
        assert!(r.peak_shard_edges < edges, "peak {} of {edges}", r.peak_shard_edges);
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn profile_shards_validates_corrupt_directories() {
    let mut rng = Pcg64::new(3);
    let g = random_graph(&mut rng, 64, 500);
    let dir = tmp_dir("corrupt");
    io::write_binary(&dir.join("shard-00000.sgg"), &g).unwrap();
    // truncate: header claims more than the file holds
    let path = dir.join("shard-00001.sgg");
    io::write_binary(&path, &g).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    std::fs::write(&path, &bytes[..bytes.len() - 16]).unwrap();
    let err = profile_shards(&dir, 2).unwrap_err();
    assert!(err.to_string().contains("bytes"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn scenario_evaluate_taps_shard_runs() {
    // end-to-end: a [evaluate] shard scenario carries structural quality
    // in its stream report, identical for 1 and 4 workers
    let dir = tmp_dir("scen");
    let toml = format!(
        "dataset = \"travel-insurance\"\n\
         [structure]\nbackend = \"erdos-renyi\"\n\
         [edge_features]\nbackend = \"random\"\n\
         [aligner]\nbackend = \"random\"\n\
         [sink]\nkind = \"shards\"\ndir = \"{}\"\n\
         [evaluate]\n",
        dir.display()
    );
    let mut reports = Vec::new();
    for workers in [1usize, 4] {
        std::fs::remove_dir_all(&dir).ok();
        let mut spec = sgg::pipeline::ScenarioSpec::parse(&toml).unwrap();
        spec.workers = workers;
        if let sgg::pipeline::SinkSpec::Shards { chunks, .. } = &mut spec.sink {
            chunks.workers = workers;
        }
        let out = sgg::pipeline::run_scenario(&spec).unwrap();
        match out {
            sgg::pipeline::SinkOutput::Streamed(r) => {
                let q = r.quality.expect("[evaluate] attached no quality");
                assert!(q.degree_dist > 0.0 && q.degree_dist <= 1.0);
                reports.push(q);
            }
            other => panic!("expected streamed output, got {other:?}"),
        }
    }
    assert_eq!(
        reports[0].degree_dist.to_bits(),
        reports[1].degree_dist.to_bits(),
        "tapped quality must be worker-count invariant"
    );
    std::fs::remove_dir_all(&dir).ok();
}
