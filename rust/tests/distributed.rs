//! The distributed-run contract: a planned multi-host run, merged, is
//! byte-identical to a single-process run from the same `.sggm` artifact
//! and seed; the folded metric profile bit-matches the single-host
//! profile; hosts writing the compact SGGEDGE2 format decode to the
//! same graph (and fold to the same profile hash) as SGGEDGE1 hosts;
//! and the manifest/merge validation rejects wrong models, overlapping
//! or missing chunk ranges, and corrupted shards loudly.

use sgg::graph::io::{self, ShardFormat};
use sgg::metrics::stream::{evaluate_shard_dirs, evaluate_shards, profile_shards};
use sgg::metrics::{degree, DegreeProfile};
use sgg::pipeline::distrib::{self, RunManifest, HOST_REPORT_FILE};
use sgg::pipeline::sink::shard_path;
use sgg::pipeline::{FittedPipeline, Pipeline, Registries, ShardSink, SizeSpec};
use sgg::structgen::chunked::ChunkConfig;
use sgg::util::json::Json;
use std::path::{Path, PathBuf};

/// Subsampled stand-in (keeps fits fast).
fn small(name: &str) -> sgg::datasets::Dataset {
    let mut ds = sgg::datasets::load(name, 3).unwrap();
    let keep: Vec<usize> = (0..ds.edges.len()).step_by(8).collect();
    ds.edge_features = ds.edge_features.gather(&keep);
    let mut edges = sgg::graph::EdgeList::new(ds.edges.spec);
    for &i in &keep {
        edges.push(ds.edges.src[i], ds.edges.dst[i]);
    }
    ds.edges = edges;
    ds
}

fn tmp_dir(name: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("sgg_distrib_{}_{name}", std::process::id()));
    std::fs::remove_dir_all(&p).ok();
    p
}

/// Fit a cheap pipeline on the subsampled stand-in, save its artifact,
/// and plan a 3-host run over the default 16-chunk decomposition.
fn setup(tag: &str) -> (PathBuf, RunManifest) {
    let ds = small("travel-insurance");
    let fitted = Pipeline::builder()
        .structure("erdos-renyi")
        .edge_features("random")
        .aligner("random")
        .fit(&ds)
        .unwrap();
    let model =
        std::env::temp_dir().join(format!("sgg_distrib_{}_{tag}.sggm", std::process::id()));
    fitted.save(&model).unwrap();
    let manifest = distrib::plan_run(&model, 3, 1, 29, 2, &Registries::builtin()).unwrap();
    assert_eq!(manifest.total_chunks, 16);
    assert_eq!(manifest.hosts.len(), 3);
    (model, manifest)
}

/// Run every planned host range into its own directory, writing shards
/// in `format`.
fn run_hosts_fmt(
    model: &Path,
    manifest: &RunManifest,
    tag: &str,
    format: ShardFormat,
) -> Vec<PathBuf> {
    manifest
        .hosts
        .iter()
        .map(|h| {
            let dir = tmp_dir(&format!("{tag}_h{}", h.host));
            distrib::run_host_range(
                model,
                manifest,
                h.start,
                h.end,
                &dir,
                2,
                false,
                format,
                &Registries::builtin(),
            )
            .unwrap();
            dir
        })
        .collect()
}

/// Run every planned host range in the default SGGEDGE1 format.
fn run_hosts(model: &Path, manifest: &RunManifest, tag: &str) -> Vec<PathBuf> {
    run_hosts_fmt(model, manifest, tag, ShardFormat::Edge1)
}

/// The reference: one process generating the whole job into one shard
/// directory, through the ordinary (non-distributed) pipeline path.
fn single_run(model: &Path, manifest: &RunManifest, tag: &str) -> PathBuf {
    let dir = tmp_dir(&format!("{tag}_single"));
    let fitted = FittedPipeline::load(model, &Registries::builtin()).unwrap();
    let cfg = ChunkConfig {
        prefix_levels: manifest.prefix_levels,
        workers: 2,
        ..ChunkConfig::default()
    };
    let mut sink = ShardSink::new(&dir, cfg).unwrap();
    let size = SizeSpec::Sized {
        n_src: manifest.n_src,
        n_dst: manifest.n_dst,
        edges: manifest.edges,
    };
    fitted.run(size, cfg, &mut sink, manifest.seed).unwrap();
    dir
}

/// Byte-compare the `.sgg` shard sets of two directories.
fn assert_same_shards(a: &Path, b: &Path) {
    let list = |d: &Path| -> Vec<String> {
        let mut v: Vec<String> = std::fs::read_dir(d)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .filter(|n| n.ends_with(".sgg"))
            .collect();
        v.sort();
        v
    };
    let (la, lb) = (list(a), list(b));
    assert_eq!(
        la,
        lb,
        "shard sets differ between {} and {}",
        a.display(),
        b.display()
    );
    for name in la {
        let bytes_a = std::fs::read(a.join(&name)).unwrap();
        let bytes_b = std::fs::read(b.join(&name)).unwrap();
        assert_eq!(bytes_a, bytes_b, "{name} differs");
    }
}

fn cleanup(model: &Path, dirs: &[PathBuf]) {
    std::fs::remove_file(model).ok();
    for d in dirs {
        std::fs::remove_dir_all(d).ok();
    }
}

#[test]
fn manifest_roundtrips_and_rejects_edits() {
    let (model, manifest) = setup("roundtrip");
    let path = std::env::temp_dir().join(format!("sgg_distrib_{}.json", std::process::id()));
    manifest.save(&path).unwrap();
    let reloaded = RunManifest::load(&path).unwrap();
    assert_eq!(reloaded, manifest);

    // a hand-edited job field breaks the spec hash
    let mut doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    if let Json::Obj(o) = &mut doc {
        o.insert("total_chunks".into(), Json::Num(15.0));
    }
    std::fs::write(&path, doc.to_string()).unwrap();
    let err = RunManifest::load(&path).unwrap_err();
    assert!(err.to_string().contains("spec_hash"), "{err}");

    // not a manifest at all
    std::fs::write(&path, "{\"a\": 1}").unwrap();
    let err = RunManifest::load(&path).unwrap_err();
    assert!(err.to_string().contains("format"), "{err}");

    std::fs::remove_file(&path).ok();
    cleanup(&model, &[]);
}

#[test]
fn three_hosts_merged_equal_one_process_bit_for_bit() {
    let (model, manifest) = setup("merge3");
    let host_dirs = run_hosts(&model, &manifest, "merge3");
    let single = single_run(&model, &manifest, "merge3");

    let merged = tmp_dir("merge3_merged");
    let reference = sgg::datasets::load(&manifest.dataset, 1).unwrap();
    let orig = DegreeProfile::of(&reference.edges);
    let report = distrib::merge_run(&manifest, &host_dirs, &merged, Some(&orig)).unwrap();

    // shard-for-shard byte identity with the single-process run
    assert_same_shards(&single, &merged);
    assert_eq!(report.edges, manifest.edges);
    assert_eq!(report.hosts, 3);

    // the folded degree profile bit-matches the single-host profile
    let (single_prof, _) = profile_shards(&single, 1).unwrap();
    assert_eq!(report.profile_hash, degree::profile_hash(&single_prof));

    // and the folded quality scores equal a streamed eval of the output
    let eval = evaluate_shards(&merged, &orig, 2).unwrap();
    let quality = report.quality.unwrap();
    assert_eq!(quality.degree_dist.to_bits(), eval.degree_dist.to_bits());
    assert_eq!(quality.dcc.to_bits(), eval.dcc.to_bits());

    let mut all = host_dirs;
    all.extend([single, merged]);
    cleanup(&model, &all);
}

#[test]
fn sggedge2_hosts_fold_to_the_sggedge1_single_process_profile() {
    let (model, manifest) = setup("xfmt");
    // hosts write the compact varint-delta format…
    let host_dirs = run_hosts_fmt(&model, &manifest, "xfmt", ShardFormat::Edge2);
    // …the reference single-process run writes the default SGGEDGE1
    let single = single_run(&model, &manifest, "xfmt");

    // merge validates the SGGEDGE2 shards (decoded-edge checksums) and
    // folds them to the exact profile of the SGGEDGE1 reference
    let merged = tmp_dir("xfmt_merged");
    let report = distrib::merge_run(&manifest, &host_dirs, &merged, None).unwrap();
    let (single_prof, _) = profile_shards(&single, 2).unwrap();
    assert_eq!(report.profile_hash, degree::profile_hash(&single_prof));
    assert_eq!(report.edges, manifest.edges);

    // every chunk present in both runs decodes to the same edge multiset
    let mut compared = 0usize;
    for chunk in 0..manifest.total_chunks {
        let p1 = shard_path(&single, chunk);
        let p2 = shard_path(&merged, chunk);
        assert_eq!(p1.exists(), p2.exists(), "chunk {chunk} presence differs");
        if !p1.exists() {
            continue;
        }
        assert_eq!(
            io::shard_decoded_checksum(&p1).unwrap(),
            io::shard_decoded_checksum(&p2).unwrap(),
            "chunk {chunk} decodes differently across formats"
        );
        compared += 1;
    }
    assert!(compared > 0, "no shards to compare");

    // the compact format actually is compact
    let dir_bytes = |d: &Path| -> u64 {
        std::fs::read_dir(d)
            .unwrap()
            .map(|e| e.unwrap().path())
            .filter(|p| p.extension().map(|x| x == "sgg").unwrap_or(false))
            .map(|p| std::fs::metadata(p).unwrap().len())
            .sum()
    };
    assert!(
        dir_bytes(&merged) < dir_bytes(&single),
        "SGGEDGE2 run should be smaller than SGGEDGE1 ({} vs {} bytes)",
        dir_bytes(&merged),
        dir_bytes(&single)
    );

    // streamed evaluation reads both formats to identical scores
    let reference = sgg::datasets::load(&manifest.dataset, 1).unwrap();
    let orig = DegreeProfile::of(&reference.edges);
    let eval1 = evaluate_shards(&single, &orig, 2).unwrap();
    let eval2 = evaluate_shards(&merged, &orig, 2).unwrap();
    assert_eq!(eval1.degree_dist.to_bits(), eval2.degree_dist.to_bits());
    assert_eq!(eval1.dcc.to_bits(), eval2.dcc.to_bits());
    assert_eq!(eval1.edges, eval2.edges);

    let mut all = host_dirs;
    all.extend([single, merged]);
    cleanup(&model, &all);
}

#[test]
fn unmerged_host_dirs_evaluate_like_the_merged_graph() {
    let (model, manifest) = setup("evaldirs");
    let host_dirs = run_hosts(&model, &manifest, "evaldirs");
    let merged = tmp_dir("evaldirs_merged");
    let reference = sgg::datasets::load(&manifest.dataset, 1).unwrap();
    let orig = DegreeProfile::of(&reference.edges);
    distrib::merge_run(&manifest, &host_dirs, &merged, None).unwrap();

    let unmerged = evaluate_shard_dirs(&host_dirs, &orig, 2).unwrap();
    let after_merge = evaluate_shards(&merged, &orig, 1).unwrap();
    assert_eq!(
        unmerged.degree_dist.to_bits(),
        after_merge.degree_dist.to_bits()
    );
    assert_eq!(unmerged.dcc.to_bits(), after_merge.dcc.to_bits());
    assert_eq!(unmerged.edges, after_merge.edges);
    assert_eq!(unmerged.shards, after_merge.shards);

    let mut all = host_dirs;
    all.push(merged);
    cleanup(&model, &all);
}

#[test]
fn host_run_resumes_to_identical_bytes_and_report() {
    let (model, manifest) = setup("resume");
    let range = manifest.hosts[1];
    let regs = Registries::builtin();

    let full = tmp_dir("resume_full");
    let (full_report, _) = distrib::run_host_range(
        &model,
        &manifest,
        range.start,
        range.end,
        &full,
        2,
        false,
        ShardFormat::Edge1,
        &regs,
    )
    .unwrap();

    // simulate an interrupted host: only a prefix of the range completed
    let resumed = tmp_dir("resume_partial");
    let mid = range.start + (range.end - range.start) / 2;
    distrib::run_host_range(
        &model,
        &manifest,
        range.start,
        mid,
        &resumed,
        2,
        false,
        ShardFormat::Edge1,
        &regs,
    )
    .unwrap();
    // the re-run with --resume picks up the intact prefix and finishes
    let (resumed_report, _) = distrib::run_host_range(
        &model,
        &manifest,
        range.start,
        range.end,
        &resumed,
        2,
        true,
        ShardFormat::Edge1,
        &regs,
    )
    .unwrap();

    assert_same_shards(&full, &resumed);
    assert_eq!(full_report, resumed_report);
    cleanup(&model, &[full, resumed]);
}

#[test]
fn wrong_model_and_wrong_range_are_rejected_before_sampling() {
    let (model, manifest) = setup("wrongmodel");
    let dir = tmp_dir("wrongmodel_h");
    let regs = Registries::builtin();

    let mut tampered = manifest.clone();
    tampered.model_hash ^= 1;
    let err = distrib::run_host_range(
        &model,
        &tampered,
        0,
        4,
        &dir,
        1,
        false,
        ShardFormat::Edge1,
        &regs,
    )
    .unwrap_err();
    assert!(err.to_string().contains("model"), "{err}");

    let err = distrib::run_host_range(
        &model,
        &manifest,
        4,
        99,
        &dir,
        1,
        false,
        ShardFormat::Edge1,
        &regs,
    )
    .unwrap_err();
    assert!(err.to_string().contains("chunk range"), "{err}");

    cleanup(&model, &[dir]);
}

#[test]
fn merge_rejects_missing_overlapping_and_corrupted_hosts() {
    let (model, manifest) = setup("reject");
    let host_dirs = run_hosts(&model, &manifest, "reject");
    let merged = tmp_dir("reject_merged");
    let reference_manifest = manifest.clone();

    // a missing host leaves a gap
    let err = distrib::merge_run(&manifest, &host_dirs[..2], &merged, None).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("cover") || msg.contains("gap"), "{msg}");

    // the same range twice overlaps
    let dup: Vec<PathBuf> = vec![
        host_dirs[0].clone(),
        host_dirs[0].clone(),
        host_dirs[1].clone(),
        host_dirs[2].clone(),
    ];
    let err = distrib::merge_run(&manifest, &dup, &merged, None).unwrap_err();
    assert!(err.to_string().contains("overlap"), "{err}");

    // a host that ran a different model is caught by its report hash
    let mut other_model = manifest.clone();
    other_model.model_hash ^= 1;
    let err = distrib::merge_run(&other_model, &host_dirs, &merged, None).unwrap_err();
    assert!(err.to_string().contains("different model"), "{err}");

    // truncating a shard breaks its header-vs-size validation
    let victim_dir = &host_dirs[1];
    let victim = std::fs::read_dir(victim_dir)
        .unwrap()
        .map(|e| e.unwrap().path())
        .find(|p| p.extension().map(|x| x == "sgg").unwrap_or(false))
        .unwrap();
    let bytes = std::fs::read(&victim).unwrap();
    std::fs::write(&victim, &bytes[..bytes.len() - 8]).unwrap();
    let err = distrib::merge_run(&reference_manifest, &host_dirs, &merged, None).unwrap_err();
    assert!(err.to_string().contains("bytes"), "{err}");

    // same-length corruption is caught by the checksum pass
    let mut flipped = bytes.clone();
    let last = flipped.len() - 1;
    flipped[last] ^= 0xff;
    std::fs::write(&victim, &flipped).unwrap();
    let err = distrib::merge_run(&reference_manifest, &host_dirs, &merged, None).unwrap_err();
    assert!(err.to_string().contains("checksum"), "{err}");

    // restoring the original bytes makes the merge pass again
    std::fs::write(&victim, &bytes).unwrap();
    distrib::merge_run(&reference_manifest, &host_dirs, &merged, None).unwrap();

    let mut all = host_dirs;
    all.push(merged);
    cleanup(&model, &all);
}

#[test]
fn host_report_is_the_completion_certificate() {
    let (model, manifest) = setup("certificate");
    let host_dirs = run_hosts(&model, &manifest, "certificate");
    let merged = tmp_dir("certificate_merged");

    // deleting one host's report makes its directory "incomplete"
    std::fs::remove_file(host_dirs[2].join(HOST_REPORT_FILE)).unwrap();
    let err = distrib::merge_run(&manifest, &host_dirs, &merged, None).unwrap_err();
    assert!(err.to_string().contains("host report"), "{err}");

    let mut all = host_dirs;
    all.push(merged);
    cleanup(&model, &all);
}
