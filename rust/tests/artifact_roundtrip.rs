//! The `.sggm` artifact contract: for every registered backend,
//! generation after `FittedPipeline::load` is bit-identical to generation
//! after fit — directly, through the parallel chunk runner at any worker
//! count, and through `run_scenario` with a `model =` spec — plus the
//! version/unknown-backend rejection paths.

use sgg::aligner::gbt::GbtConfig;
use sgg::pipeline::{
    run_scenario, ComponentSpec, FittedPipeline, MemorySink, Pipeline, PipelineBuilder,
    Registries, ScenarioSpec, SizeSpec, SGGM_VERSION,
};
use sgg::structgen::chunked::ChunkConfig;
use sgg::util::json::Json;
use std::path::PathBuf;

/// Subsampled stand-in (keeps learned-aligner fits fast).
fn small(name: &str) -> sgg::datasets::Dataset {
    let mut ds = sgg::datasets::load(name, 3).unwrap();
    let keep: Vec<usize> = (0..ds.edges.len()).step_by(8).collect();
    ds.edge_features = ds.edge_features.gather(&keep);
    let mut edges = sgg::graph::EdgeList::new(ds.edges.spec);
    for &i in &keep {
        edges.push(ds.edges.src[i], ds.edges.dst[i]);
    }
    ds.edges = edges;
    ds
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("sgg_artifact_{}_{name}.sggm", std::process::id()))
}

/// Save → load → compare `generate` output bit-for-bit.
fn assert_roundtrip(builder: PipelineBuilder, ds: &sgg::datasets::Dataset, tag: &str) {
    let fitted = builder.fit(ds).unwrap();
    let direct = fitted.generate(1, 7).unwrap();
    let path = tmp(tag);
    fitted.save(&path).unwrap();
    let loaded = FittedPipeline::load(&path, &Registries::builtin()).unwrap();
    assert_eq!(loaded.name, fitted.name, "{tag}");
    assert_eq!(loaded.seed(), fitted.seed(), "{tag}");
    assert_eq!(loaded.source(), fitted.source(), "{tag}");
    let re = loaded.generate(1, 7).unwrap();
    assert_eq!(direct.edges.src, re.edges.src, "{tag}: structure diverged");
    assert_eq!(direct.edges.dst, re.edges.dst, "{tag}: structure diverged");
    assert_eq!(direct.edge_features, re.edge_features, "{tag}: edge features diverged");
    assert_eq!(direct.node_features, re.node_features, "{tag}: node features diverged");
    std::fs::remove_file(path).ok();
}

#[test]
fn every_structure_backend_roundtrips() {
    let ds = small("travel-insurance");
    for sk in ["kronecker", "kronecker-noisy", "erdos-renyi", "sbm", "trilliong"] {
        assert_roundtrip(
            Pipeline::builder().structure(sk).edge_features("random").aligner("random"),
            &ds,
            sk,
        );
    }
}

#[test]
fn every_feature_backend_roundtrips() {
    let ds = small("travel-insurance");
    for fk in ["kde", "random", "gaussian"] {
        assert_roundtrip(
            Pipeline::builder().structure("erdos-renyi").edge_features(fk).aligner("random"),
            &ds,
            fk,
        );
    }
    // gan: force the host-resident resample backend (PJRT device state
    // is rejected at save time by design)
    assert_roundtrip(
        Pipeline::builder()
            .structure("erdos-renyi")
            .edge_features(ComponentSpec::new("gan").with("use_pjrt", false))
            .aligner("random"),
        &ds,
        "gan",
    );
}

#[test]
fn every_aligner_backend_roundtrips() {
    let ds = small("travel-insurance");
    let fast = GbtConfig { n_trees: 5, ..GbtConfig::fast() };
    for ak in ["learned", "random"] {
        assert_roundtrip(
            Pipeline::builder()
                .structure("erdos-renyi")
                .edge_features("random")
                .aligner(ak)
                .gbt(fast.clone()),
            &ds,
            ak,
        );
    }
}

#[test]
fn node_feature_leg_roundtrips() {
    // ieee-fraud carries node features → the artifact holds five
    // components (structure + two feature generators + two aligners)
    let ds = small("ieee-fraud");
    assert!(ds.node_features.is_some());
    assert_roundtrip(
        Pipeline::builder()
            .edge_features("kde")
            .gbt(GbtConfig { n_trees: 4, ..GbtConfig::fast() }),
        &ds,
        "node-leg",
    );
}

#[test]
fn loaded_pipeline_is_worker_count_invariant_and_matches_fit() {
    let ds = small("travel-insurance");
    let fitted = Pipeline::builder()
        .structure("kronecker")
        .edge_features("random")
        .aligner("random")
        .fit(&ds)
        .unwrap();
    let path = tmp("workers");
    fitted.save(&path).unwrap();
    let loaded = FittedPipeline::load(&path, &Registries::builtin()).unwrap();
    let run = |p: &FittedPipeline, workers: usize| {
        let cfg = ChunkConfig { prefix_levels: 2, workers, queue_capacity: 2, ..ChunkConfig::default() };
        let mut sink = MemorySink::new();
        p.run(SizeSpec::Scale(1), cfg, &mut sink, 13)
            .unwrap()
            .into_dataset()
            .unwrap()
    };
    let reference = run(&fitted, 1);
    for workers in [1usize, 2, 4] {
        let par = run(&loaded, workers);
        assert_eq!(reference.edges.src, par.edges.src, "workers={workers}");
        assert_eq!(reference.edges.dst, par.edges.dst, "workers={workers}");
        assert_eq!(reference.edge_features, par.edge_features, "workers={workers}");
    }
    std::fs::remove_file(path).ok();
}

#[test]
fn scenario_model_key_generates_from_artifact_without_dataset() {
    let ds = small("travel-insurance");
    let fitted = Pipeline::builder()
        .structure("erdos-renyi")
        .edge_features("random")
        .aligner("random")
        .fit(&ds)
        .unwrap();
    let path = tmp("scenario");
    fitted.save(&path).unwrap();

    let spec = ScenarioSpec::parse(&format!(
        "model = \"{}\"\nseed = 13\nworkers = 2\n",
        path.display()
    ))
    .unwrap();
    assert!(spec.dataset.is_empty());
    let via_spec = run_scenario(&spec).unwrap().into_dataset().unwrap();

    // same config the scenario runner uses: default chunking, workers=2
    let cfg = ChunkConfig { workers: 2, ..ChunkConfig::default() };
    let mut sink = MemorySink::new();
    let direct = fitted
        .run(SizeSpec::Scale(1), cfg, &mut sink, 13)
        .unwrap()
        .into_dataset()
        .unwrap();
    assert_eq!(direct.edges.src, via_spec.edges.src);
    assert_eq!(direct.edges.dst, via_spec.edges.dst);
    assert_eq!(direct.edge_features, via_spec.edge_features);
    std::fs::remove_file(path).ok();
}

#[test]
fn artifact_header_records_format_seed_and_source() {
    let ds = small("travel-insurance");
    let fitted = Pipeline::builder()
        .structure("erdos-renyi")
        .edge_features("random")
        .aligner("random")
        .seed(0xfeed)
        .fit(&ds)
        .unwrap();
    let doc = fitted.to_artifact_json().unwrap();
    assert_eq!(doc.req_str("format").unwrap(), "sggm");
    assert_eq!(doc.req_u64("version").unwrap(), SGGM_VERSION);
    assert_eq!(doc.req_u64("seed").unwrap(), 0xfeed);
    let src = doc.req("source").unwrap();
    assert_eq!(src.req_str("dataset").unwrap(), "travel-insurance");
    assert_eq!(src.req_u64("edges").unwrap(), ds.edges.len() as u64);
    assert!(!src.req_strs("edge_feature_cols").unwrap().is_empty());
}

#[test]
fn provenance_reads_without_deserializing_components() {
    let ds = small("travel-insurance");
    let fitted = Pipeline::builder()
        .structure("erdos-renyi")
        .edge_features("random")
        .aligner("random")
        .fit(&ds)
        .unwrap();
    let path = tmp("provenance");
    fitted.save(&path).unwrap();
    // header-only read matches the fully-loaded pipeline's provenance
    let src = FittedPipeline::read_provenance(&path).unwrap();
    assert_eq!(&src, fitted.source());
    assert_eq!(src.dataset, "travel-insurance");
    // same format guard as the full load path
    std::fs::write(&path, "{\"format\": \"other\"}").unwrap();
    let err = FittedPipeline::read_provenance(&path).unwrap_err();
    assert!(err.to_string().contains("format"), "{err}");
    std::fs::remove_file(path).ok();
}

#[test]
fn version_mismatch_is_rejected_with_clear_error() {
    let ds = small("travel-insurance");
    let fitted = Pipeline::builder()
        .structure("erdos-renyi")
        .edge_features("random")
        .aligner("random")
        .fit(&ds)
        .unwrap();
    let mut doc = fitted.to_artifact_json().unwrap();
    if let Json::Obj(o) = &mut doc {
        o.insert("version".into(), Json::Num(99.0));
    }
    let err = FittedPipeline::from_artifact_json(&doc, &Registries::builtin()).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("version") && msg.contains("99"), "{msg}");
}

#[test]
fn wrong_format_and_unknown_backend_are_rejected() {
    let regs = Registries::builtin();
    // not an artifact at all
    let err =
        FittedPipeline::from_artifact_json(&Json::parse("{\"a\":1}").unwrap(), &regs).unwrap_err();
    assert!(err.to_string().contains("format"), "{err}");
    let err = FittedPipeline::from_artifact_json(
        &Json::parse("{\"format\":\"zip\"}").unwrap(),
        &regs,
    )
    .unwrap_err();
    assert!(err.to_string().contains("zip"), "{err}");

    // a valid artifact with a tampered structure backend name: the error
    // must name the offender and list what IS registered
    let ds = small("travel-insurance");
    let fitted = Pipeline::builder()
        .structure("erdos-renyi")
        .edge_features("random")
        .aligner("random")
        .fit(&ds)
        .unwrap();
    let mut doc = fitted.to_artifact_json().unwrap();
    if let Json::Obj(o) = &mut doc {
        if let Some(Json::Obj(structure)) = o.get_mut("structure") {
            structure.insert("backend".into(), Json::Str("warp-drive".into()));
        }
    }
    let err = FittedPipeline::from_artifact_json(&doc, &regs).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("warp-drive"), "{msg}");
    assert!(msg.contains("kronecker"), "{msg}");
}

#[test]
fn load_survives_disk_roundtrip_of_large_state() {
    // the SBM state is the largest (per-node tables); make sure the
    // serialized text parses back identically after a real disk write
    let ds = small("tabformer");
    let fitted = Pipeline::builder()
        .structure(ComponentSpec::new("sbm").with("blocks", 8u64))
        .edge_features("gaussian")
        .aligner("random")
        .fit(&ds)
        .unwrap();
    let path = tmp("disk");
    fitted.save(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let reparsed = Json::parse(&text).unwrap();
    assert_eq!(reparsed, fitted.to_artifact_json().unwrap());
    let loaded = FittedPipeline::load(&path, &Registries::builtin()).unwrap();
    let a = fitted.generate(2, 5).unwrap();
    let b = loaded.generate(2, 5).unwrap();
    assert_eq!(a.edges.src, b.edges.src);
    assert_eq!(a.edge_features, b.edge_features);
    std::fs::remove_file(path).ok();
}
