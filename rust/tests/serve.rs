//! The `sgg serve` service contract: an HTTP job is byte-identical to
//! `sgg run` on the same spec/seed/workers and streams the same
//! canonical `StreamReport` JSON `--json` prints; refitting an
//! identical spec is a cache hit whose artifact round-trips through
//! `GET /artifacts/<hash>`; a full admission queue answers `429` with
//! `Retry-After`; and a cancelled job stops at a chunk boundary leaving
//! a consecutive, resumable shard prefix.

use sgg::pipeline::{
    run_scenario_opts, Registries, RunOptions, ScenarioSpec, SinkOutput, StreamReport,
};
use sgg::serve::{parse_hash, ServeConfig, Server, ServerHandle};
use sgg::util::json::Json;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

fn tmp(name: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("sgg_serve_{}_{name}", std::process::id()));
    std::fs::remove_dir_all(&p).ok();
    std::fs::create_dir_all(&p).unwrap();
    p
}

/// Start a background server on an ephemeral port.
fn start(cache_dir: &Path, workers: usize, queue_depth: usize) -> ServerHandle {
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        cache_dir: cache_dir.to_path_buf(),
        workers,
        queue_depth,
    };
    Server::bind(&cfg).unwrap().spawn().unwrap()
}

/// Minimal blocking HTTP/1.1 client: one request, read to close.
/// Returns (status, head, body).
fn http(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, String, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: sgg\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes()).unwrap();
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).unwrap();
    let text = String::from_utf8(raw).unwrap();
    let (head, body) = text.split_once("\r\n\r\n").unwrap();
    let status: u16 = head.split_whitespace().nth(1).unwrap().parse().unwrap();
    (status, head.to_string(), body.to_string())
}

fn submitted_job_id(addr: SocketAddr, spec: &str) -> u64 {
    let (status, _, body) = http(addr, "POST", "/jobs", spec);
    assert_eq!(status, 202, "{body}");
    let doc = Json::parse(body.trim()).unwrap();
    doc.get("job").and_then(|j| j.as_f64()).unwrap() as u64
}

/// Sorted shard files (`*.sgg`) of a directory (empty when the sink
/// has not created the directory yet).
fn shard_files(dir: &Path) -> Vec<PathBuf> {
    let Ok(entries) = std::fs::read_dir(dir) else { return Vec::new() };
    let mut files: Vec<PathBuf> = entries
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().is_some_and(|x| x == "sgg"))
        .collect();
    files.sort();
    files
}

fn scenario(dir: &Path) -> String {
    format!(
        r#"
name = "serve-test"
dataset = "travel-insurance"
seed = 33
workers = 2

[structure]
backend = "erdos-renyi"

[edge_features]
backend = "random"

[aligner]
backend = "random"

[sink]
kind = "shards"
dir = "{}"
"#,
        dir.display()
    )
}

#[test]
fn http_job_is_byte_identical_to_cli_run_and_streams_canonical_json() {
    let root = tmp("identity");
    let http_dir = root.join("via-http");
    let cli_dir = root.join("via-cli");
    let server = start(&root.join("cache"), 2, 4);
    let addr = server.addr();

    let id = submitted_job_id(addr, &scenario(&http_dir));
    // the blocking GET streams NDJSON until the job is terminal
    let (status, head, body) = http(addr, "GET", &format!("/jobs/{id}"), "");
    assert_eq!(status, 200);
    assert!(head.contains("application/x-ndjson"), "{head}");
    let lines: Vec<&str> = body.lines().filter(|l| !l.is_empty()).collect();
    assert!(!lines.is_empty());
    // every line is the canonical StreamReport serialization
    for line in &lines {
        let doc = Json::parse(line).unwrap();
        StreamReport::from_json(&doc).unwrap();
    }
    let final_report =
        StreamReport::from_json(&Json::parse(lines.last().unwrap()).unwrap()).unwrap();
    assert!(final_report.shards > 0);
    assert!(final_report.edges_written > 0);

    // the CLI on the same spec (different dir), with --json: the same
    // canonical serialization, and byte-identical shards
    let spec_path = root.join("cli.toml");
    std::fs::write(&spec_path, scenario(&cli_dir)).unwrap();
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_sgg"))
        .args(["run", spec_path.to_str().unwrap(), "--json"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8(out.stdout).unwrap();
    let cli_report =
        StreamReport::from_json(&Json::parse(stdout.trim().lines().last().unwrap()).unwrap())
            .unwrap();
    assert_eq!(cli_report.edges_written, final_report.edges_written);
    assert_eq!(cli_report.shards, final_report.shards);

    let http_shards = shard_files(&http_dir);
    let cli_shards = shard_files(&cli_dir);
    assert_eq!(http_shards.len(), cli_shards.len());
    assert!(!http_shards.is_empty());
    for (a, b) in http_shards.iter().zip(&cli_shards) {
        assert_eq!(a.file_name(), b.file_name());
        assert_eq!(
            std::fs::read(a).unwrap(),
            std::fs::read(b).unwrap(),
            "shard {:?} differs between HTTP job and CLI run",
            a.file_name()
        );
    }
    server.stop();
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn refit_is_a_cache_hit_and_artifacts_are_fetchable() {
    let root = tmp("fit");
    let server = start(&root.join("cache"), 1, 4);
    let addr = server.addr();
    let fit_spec = r#"
dataset = "travel-insurance"
seed = 9

[structure]
backend = "erdos-renyi"

[edge_features]
backend = "random"

[aligner]
backend = "random"
"#;

    let (status, _, body) = http(addr, "POST", "/fit", fit_spec);
    assert_eq!(status, 201, "{body}");
    let first = Json::parse(body.trim()).unwrap();
    assert_eq!(first.get("cached").and_then(|c| c.as_bool()), Some(false));
    let hash = first.get("model").and_then(|m| m.as_str()).unwrap().to_string();
    assert!(parse_hash(&hash).is_some(), "{hash}");

    // identical spec → cache hit, same artifact, no refit
    let (status, _, body) = http(addr, "POST", "/fit", fit_spec);
    assert_eq!(status, 200, "{body}");
    let second = Json::parse(body.trim()).unwrap();
    assert_eq!(second.get("cached").and_then(|c| c.as_bool()), Some(true));
    assert_eq!(second.get("model").and_then(|m| m.as_str()), Some(hash.as_str()));

    // the artifact fetches byte-for-byte and loads as a pipeline
    let (status, _, body) = http(addr, "GET", &format!("/artifacts/{hash}"), "");
    assert_eq!(status, 200);
    let fetched = root.join("fetched.sggm");
    std::fs::write(&fetched, &body).unwrap();
    let loaded =
        sgg::pipeline::FittedPipeline::load(&fetched, &Registries::builtin()).unwrap();
    assert_eq!(loaded.source().dataset, "travel-insurance");

    let (status, _, _) = http(addr, "GET", "/artifacts/ffffffffffffffff", "");
    assert_eq!(status, 404);
    let (status, _, _) = http(addr, "GET", "/artifacts/not-a-hash", "");
    assert_eq!(status, 404);
    server.stop();
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn full_queue_answers_429_with_retry_after() {
    let root = tmp("backpressure");
    // no workers: admitted jobs stay queued, pinning queue occupancy
    let server = start(&root.join("cache"), 0, 1);
    let addr = server.addr();

    let id = submitted_job_id(addr, &scenario(&root.join("a")));
    let (status, head, body) = http(addr, "POST", "/jobs", &scenario(&root.join("b")));
    assert_eq!(status, 429, "{body}");
    assert!(head.lines().any(|l| l.to_ascii_lowercase().starts_with("retry-after:")), "{head}");

    // unknown jobs are 404; cancelling the queued job frees nothing in
    // the closed queue but flips its state immediately
    let (status, _, _) = http(addr, "GET", "/jobs/999", "");
    assert_eq!(status, 404);
    let (status, _, body) = http(addr, "DELETE", &format!("/jobs/{id}"), "");
    assert_eq!(status, 200, "{body}");
    let (status, _, body) = http(addr, "GET", &format!("/jobs/{id}?wait=0"), "");
    assert_eq!(status, 200);
    let doc = Json::parse(body.trim()).unwrap();
    assert_eq!(doc.get("state").and_then(|s| s.as_str()), Some("cancelled"));
    server.stop();
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn cancelled_job_leaves_a_resumable_prefix() {
    let root = tmp("cancel");
    let out_dir = root.join("cancelled");
    let clean_dir = root.join("clean");
    let server = start(&root.join("cache"), 1, 2);
    let addr = server.addr();

    // one worker, 64 sequential chunks: slow enough to cancel mid-run
    let spec_text = format!(
        r#"
name = "serve-cancel"
dataset = "travel-insurance"
seed = 17
workers = 1

[structure]
backend = "erdos-renyi"

[edge_features]
backend = "random"

[aligner]
backend = "random"

[size]
n_src = 65536
edges = 2000000

[sink]
kind = "shards"
dir = "{}"
prefix_levels = 3
"#,
        out_dir.display()
    );
    let id = submitted_job_id(addr, &spec_text);

    // cancel as soon as the first shard lands
    let deadline = Instant::now() + Duration::from_secs(120);
    while shard_files(&out_dir).is_empty() {
        assert!(Instant::now() < deadline, "no shard ever appeared");
        std::thread::sleep(Duration::from_millis(2));
    }
    let (status, _, body) = http(addr, "DELETE", &format!("/jobs/{id}"), "");
    assert_eq!(status, 200, "{body}");

    // the blocking stream terminates with the cancellation marker
    // (unless the tiny job already finished — then it's a full report)
    let (_, _, body) = http(addr, "GET", &format!("/jobs/{id}"), "");
    let last = Json::parse(body.lines().filter(|l| !l.is_empty()).last().unwrap()).unwrap();
    let cancelled_mid_run = last.get("cancelled").and_then(|c| c.as_bool()) == Some(true);

    // whatever was written is a consecutive prefix shard-00000..k
    let prefix = shard_files(&out_dir);
    assert!(!prefix.is_empty());
    for (i, path) in prefix.iter().enumerate() {
        assert_eq!(
            path.file_name().unwrap().to_str().unwrap(),
            format!("shard-{i:05}.sgg"),
            "hole in the shard prefix"
        );
    }
    if cancelled_mid_run {
        assert!(prefix.len() < 64, "cancel landed but every chunk was written");
    }

    // resuming the cancelled directory completes it byte-identically to
    // an uninterrupted run of the same spec
    let spec = ScenarioSpec::parse(&spec_text).unwrap();
    let opts = RunOptions { resume: true, ..RunOptions::default() };
    match run_scenario_opts(&spec, &Registries::builtin(), opts).unwrap() {
        SinkOutput::Streamed(report) => assert_eq!(report.shards, 64),
        SinkOutput::Dataset(_) => panic!("expected a streamed run"),
    }
    let mut clean_spec = ScenarioSpec::parse(&spec_text).unwrap();
    clean_spec.sink = sgg::pipeline::SinkSpec::Shards {
        dir: clean_dir.clone(),
        chunks: match &spec.sink {
            sgg::pipeline::SinkSpec::Shards { chunks, .. } => *chunks,
            sgg::pipeline::SinkSpec::Memory => unreachable!(),
        },
    };
    run_scenario_opts(&clean_spec, &Registries::builtin(), RunOptions::default()).unwrap();
    let resumed = shard_files(&out_dir);
    let clean = shard_files(&clean_dir);
    assert_eq!(resumed.len(), clean.len());
    for (a, b) in resumed.iter().zip(&clean) {
        assert_eq!(
            std::fs::read(a).unwrap(),
            std::fs::read(b).unwrap(),
            "shard {:?} differs after resume",
            a.file_name()
        );
    }
    server.stop();
    std::fs::remove_dir_all(&root).ok();
}
