//! Bench: regenerates paper Table 8 (ER generation timings, E sweep).
//!
//! Run: `cargo bench --bench table8_random_timings`

fn main() {
    let t0 = std::time::Instant::now();
    sgg::experiments::table8::run(false).expect("table8");
    println!("\n[bench] table8 end-to-end: {:.2}s", t0.elapsed().as_secs_f64());
}
