//! Bench: regenerates paper Figure 8 (generator throughput curves) —
//! the headline performance claim; this is the §Perf measurement target.
//!
//! Run: `cargo bench --bench figure8_throughput`

fn main() {
    let t0 = std::time::Instant::now();
    sgg::experiments::figure8::run(false).expect("figure8");
    println!("\n[bench] figure8 end-to-end: {:.2}s", t0.elapsed().as_secs_f64());
}
