//! Bench: regenerates paper Table 2 (quality vs baselines) end-to-end and
//! times each (dataset, method) cell. Custom harness (criterion is not
//! available offline).
//!
//! Run: `cargo bench --bench table2_quality`

fn main() {
    let t0 = std::time::Instant::now();
    sgg::experiments::table2::run(true).expect("table2");
    println!("\n[bench] table2 end-to-end: {:.2}s", t0.elapsed().as_secs_f64());
}
