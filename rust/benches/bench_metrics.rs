//! Bench: streamed shard evaluation vs in-memory materialization.
//!
//! Generates a seeded Kronecker graph to disk shards (exercising the
//! batched shard writer), then evaluates it against a reference graph
//! two ways: (a) materialize every shard into one edge list and score
//! it, (b) stream the shards through the mergeable degree accumulators
//! at 1/2/4 workers. Asserts the streamed scores are **bit-identical**
//! to the in-memory ones at every worker count, and emits
//! `BENCH_metrics.json` with shard read/write throughput and the memory
//! evidence: streamed peak memory is bounded by the largest shard (plus
//! the O(nodes) degree arrays), not by the edge count. A format-matrix
//! pass re-streams the same graph as compact varint-delta `SGGEDGE2`
//! shards, asserts the streamed scores still bit-match, and records the
//! on-disk size of both formats.
//!
//! Run: `cargo bench --bench bench_metrics`
//! Knobs: `SGG_BENCH_EDGES` (default 4_000_000), `SGG_BENCH_NODES`
//! (default 1 << 19).

use sgg::graph::io::ShardFormat;
use sgg::graph::PartiteSpec;
use sgg::metrics::degree::{degree_dist_score_profiles, dcc_profiles};
use sgg::metrics::stream::{evaluate_shards, DCC_SAMPLES};
use sgg::metrics::DegreeProfile;
use sgg::pipeline::orchestrator::{read_shards, stream_to_shards};
use sgg::structgen::chunked::{generate_chunked_collect, ChunkConfig};
use sgg::structgen::kronecker::KroneckerGen;
use sgg::structgen::theta::ThetaS;
use sgg::util::json::Json;

fn env_u64(key: &str, default: u64) -> u64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let nodes = env_u64("SGG_BENCH_NODES", 1 << 19);
    let edges = env_u64("SGG_BENCH_EDGES", 4_000_000);
    let gen = KroneckerGen::new(ThetaS::rmat_default(), PartiteSpec::square(nodes), edges);
    let dir = std::env::temp_dir().join(format!("sgg_bench_metrics_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();

    // --- generate the "synthetic" side to shards (batched writer) ---
    let cfg = ChunkConfig { prefix_levels: 3, workers: 4, queue_capacity: 4, ..ChunkConfig::default() };
    let t0 = std::time::Instant::now();
    let report = stream_to_shards(&gen, nodes, nodes, edges, 7, cfg, &dir).expect("stream");
    let write_secs = t0.elapsed().as_secs_f64();
    assert_eq!(report.edges_written, edges);
    println!(
        "[bench] shard write: {} edges in {} shards, {write_secs:.2}s ({:.1} Medges/s)",
        edges,
        report.shards,
        edges as f64 / write_secs.max(1e-9) / 1e6
    );

    // --- the "original" reference: a second seed, kept in memory ---
    let reference = generate_chunked_collect(&gen, nodes, nodes, edges / 4, 11, cfg)
        .expect("reference generation");
    let orig = DegreeProfile::of(&reference);
    drop(reference);

    // --- in-memory baseline: materialize every shard, then score ---
    let t0 = std::time::Instant::now();
    let whole = read_shards(&dir).expect("read shards");
    let read_secs = t0.elapsed().as_secs_f64();
    let mem_bytes = whole.len() as u64 * 16;
    let synth_prof = DegreeProfile::of(&whole);
    let mem_score = degree_dist_score_profiles(&orig, &synth_prof);
    let mem_dcc = dcc_profiles(&orig, &synth_prof, DCC_SAMPLES);
    drop(synth_prof);
    drop(whole);
    println!(
        "[bench] in-memory: read+materialize {read_secs:.2}s ({:.1} Medges/s), \
         resident {mem_bytes} bytes, degree_dist={mem_score:.4}",
        edges as f64 / read_secs.max(1e-9) / 1e6
    );

    // --- streamed evaluation at several worker counts ---
    let mut runs: Vec<Json> = Vec::new();
    let mut peak_shard_edges = 0u64;
    let mut profile_bytes = 0u64;
    for workers in [1usize, 2, 4] {
        let t0 = std::time::Instant::now();
        let r = evaluate_shards(&dir, &orig, workers).expect("streamed eval");
        let secs = t0.elapsed().as_secs_f64();
        assert_eq!(
            r.degree_dist.to_bits(),
            mem_score.to_bits(),
            "streamed degree_dist diverged from in-memory at {workers} workers"
        );
        assert_eq!(
            r.dcc.to_bits(),
            mem_dcc.to_bits(),
            "streamed dcc diverged from in-memory at {workers} workers"
        );
        peak_shard_edges = r.peak_shard_edges;
        profile_bytes = r.profile_bytes;
        println!(
            "[bench] streamed eval workers={workers}: {secs:.2}s ({:.1} Medges/s), \
             peak shard {} edges",
            edges as f64 / secs.max(1e-9) / 1e6,
            r.peak_shard_edges
        );
        runs.push(Json::obj(vec![
            ("workers", Json::from(workers)),
            ("secs", Json::from(secs)),
            ("edges_per_sec", Json::from(edges as f64 / secs.max(1e-9))),
        ]));
    }

    // --- format matrix: the same graph as compact SGGEDGE2 shards ---
    let dir2 = std::env::temp_dir().join(format!("sgg_bench_metrics2_{}", std::process::id()));
    std::fs::remove_dir_all(&dir2).ok();
    let cfg2 = ChunkConfig { format: ShardFormat::Edge2, ..cfg };
    let t0 = std::time::Instant::now();
    let report2 = stream_to_shards(&gen, nodes, nodes, edges, 7, cfg2, &dir2).expect("stream e2");
    let write2_secs = t0.elapsed().as_secs_f64();
    assert_eq!(report2.edges_written, edges);
    let dir_bytes = |d: &std::path::Path| -> u64 {
        std::fs::read_dir(d)
            .unwrap()
            .map(|e| e.unwrap().metadata().unwrap().len())
            .sum()
    };
    let (b1, b2) = (dir_bytes(&dir), dir_bytes(&dir2));
    let r2 = evaluate_shards(&dir2, &orig, 4).expect("streamed eval over SGGEDGE2");
    assert_eq!(
        r2.degree_dist.to_bits(),
        mem_score.to_bits(),
        "SGGEDGE2 eval diverged from in-memory"
    );
    assert_eq!(r2.dcc.to_bits(), mem_dcc.to_bits(), "SGGEDGE2 dcc diverged from in-memory");
    assert!(
        b2 * 2 <= b1,
        "SGGEDGE2 ({b2} B) should be at least 2x smaller than SGGEDGE1 ({b1} B)"
    );
    println!(
        "[bench] formats: sggedge1 {b1} B, sggedge2 {b2} B ({:.2}x smaller), \
         sggedge2 write {write2_secs:.2}s",
        b1 as f64 / b2.max(1) as f64
    );

    // memory evidence: the streamed pass holds at most one shard per
    // worker plus the O(nodes) degree arrays — bounded by chunk size,
    // not by the total edge count
    let peak_chunk_bytes = peak_shard_edges * 16;
    assert!(
        peak_chunk_bytes < mem_bytes / 2,
        "peak shard ({peak_chunk_bytes} B) should be far below full \
         materialization ({mem_bytes} B)"
    );

    let out = Json::obj(vec![
        (
            "scenario",
            Json::obj(vec![
                ("generator", Json::from("kronecker (rmat default theta)")),
                ("nodes", Json::from(nodes)),
                ("edges", Json::from(edges)),
                ("shards", Json::from(report.shards)),
                ("prefix_levels", Json::from(3u64)),
            ]),
        ),
        (
            "shard_write",
            Json::obj(vec![
                ("secs", Json::from(write_secs)),
                ("edges_per_sec", Json::from(edges as f64 / write_secs.max(1e-9))),
            ]),
        ),
        (
            "shard_read_in_memory",
            Json::obj(vec![
                ("secs", Json::from(read_secs)),
                ("edges_per_sec", Json::from(edges as f64 / read_secs.max(1e-9))),
                ("resident_bytes", Json::from(mem_bytes)),
            ]),
        ),
        ("streamed_eval", Json::Arr(runs)),
        ("streamed_matches_in_memory_bit_for_bit", Json::from(true)),
        (
            "shard_formats",
            Json::obj(vec![
                ("sggedge1_bytes", Json::from(b1)),
                ("sggedge2_bytes", Json::from(b2)),
                ("compression_ratio", Json::from(b1 as f64 / b2.max(1) as f64)),
                ("sggedge2_write_secs", Json::from(write2_secs)),
                ("eval_matches_bit_for_bit", Json::from(true)),
            ]),
        ),
        (
            "memory",
            Json::obj(vec![
                ("full_materialization_bytes", Json::from(mem_bytes)),
                ("peak_shard_chunk_bytes", Json::from(peak_chunk_bytes)),
                ("degree_profile_bytes", Json::from(profile_bytes)),
                ("bounded_by_chunk_not_edge_count", Json::from(true)),
            ]),
        ),
    ]);
    std::fs::write("BENCH_metrics.json", format!("{out}\n")).expect("write BENCH_metrics.json");
    println!(
        "[bench] wrote BENCH_metrics.json (peak chunk {peak_chunk_bytes} B vs \
         full {mem_bytes} B)"
    );
    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&dir2).ok();
}
