//! Bench: sequential vs multi-worker chunked generation throughput.
//!
//! Runs the same seeded Kronecker scenario (R-MAT θ, 2²⁰ nodes) through
//! the parallel chunk runner at 1/2/4/8 workers, verifies every run
//! produces the identical edge stream (checksum), and emits
//! `BENCH_parallel.json` with edges/sec per worker count — CI uploads it
//! as an artifact. The single-worker run doubles as the hot-path
//! regression gate for the batched PRNG/alias sampling and the chunk
//! buffer arena: `sequential_edges_per_sec` is tracked at the top level.
//!
//! A second stage runs the full shard path (worker-side SGGEDGE2
//! encoding + overlapped shard IO) at 1 vs 4 workers, byte-compares the
//! two directories, and records the stage-time breakdown
//! (`sample_secs`/`encode_secs`/`write_secs`/`writer_busy_secs`) the
//! [`StreamReport`](sgg::pipeline::StreamReport) now carries.
//!
//! Run: `cargo bench --bench bench_parallel`
//! Knobs: `SGG_BENCH_EDGES` (default 8_000_000), `SGG_BENCH_NODES`
//! (default 1 << 20).

use sgg::graph::io::ShardFormat;
use sgg::graph::PartiteSpec;
use sgg::pipeline::orchestrator::stream_to_shards;
use sgg::structgen::chunked::{generate_chunked, ChunkConfig};
use sgg::structgen::kronecker::KroneckerGen;
use sgg::structgen::theta::ThetaS;
use sgg::util::json::Json;

fn env_u64(key: &str, default: u64) -> u64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let nodes = env_u64("SGG_BENCH_NODES", 1 << 20);
    let edges = env_u64("SGG_BENCH_EDGES", 8_000_000);
    let seed = 0x5a6e;
    let gen = KroneckerGen::new(ThetaS::rmat_default(), PartiteSpec::square(nodes), edges);

    let mut runs: Vec<Json> = Vec::new();
    let mut seq_eps = 0.0f64;
    let mut checksum0: Option<u64> = None;
    let mut speedup_at_4 = 0.0f64;
    for workers in [1usize, 2, 4, 8] {
        let cfg = ChunkConfig { prefix_levels: 3, workers, queue_capacity: 4, ..ChunkConfig::default() };
        // cheap order-sensitive checksum proves runs are bit-identical
        let mut checksum = 0u64;
        let t0 = std::time::Instant::now();
        let total = generate_chunked(&gen, nodes, nodes, edges, seed, cfg, |chunk| {
            for (s, d) in chunk.edges.iter() {
                checksum = checksum
                    .rotate_left(1)
                    .wrapping_add(s.wrapping_mul(0x9e37_79b9).wrapping_add(d));
            }
            Ok(())
        })
        .expect("bench generation failed");
        let secs = t0.elapsed().as_secs_f64();
        assert_eq!(total, edges, "wrong edge count at {workers} workers");
        match checksum0 {
            None => checksum0 = Some(checksum),
            Some(c) => assert_eq!(
                c, checksum,
                "output changed at {workers} workers — determinism broken"
            ),
        }
        let eps = edges as f64 / secs.max(1e-9);
        if workers == 1 {
            seq_eps = eps;
        }
        let speedup = eps / seq_eps.max(1e-9);
        if workers == 4 {
            speedup_at_4 = speedup;
        }
        println!(
            "[bench] workers={workers:2}  {secs:6.2}s  {:8.2} Medges/s  speedup {speedup:.2}x",
            eps / 1e6
        );
        runs.push(Json::obj(vec![
            ("workers", Json::from(workers)),
            ("secs", Json::from(secs)),
            ("edges_per_sec", Json::from(eps)),
            ("speedup_vs_sequential", Json::from(speedup)),
        ]));
    }

    // Streamed-shard stage: the same scenario through the full
    // worker-encode → overlapped-IO shard path (SGGEDGE2), 1 vs 4
    // workers. Byte-comparing the two directories is the determinism
    // gate for the encoded path; the stage-time breakdown shows where
    // the wall clock went.
    let bench_dir =
        std::env::temp_dir().join(format!("sgg_bench_stream_{}", std::process::id()));
    let mut streamed: Vec<Json> = Vec::new();
    let mut stream_seq_eps = 0.0f64;
    let mut stream_speedup_at_4 = 0.0f64;
    let mut dirs = Vec::new();
    for workers in [1usize, 4] {
        let dir = bench_dir.join(format!("w{workers}"));
        std::fs::remove_dir_all(&dir).ok();
        let cfg = ChunkConfig {
            prefix_levels: 3,
            workers,
            queue_capacity: 4,
            format: ShardFormat::Edge2,
            ..ChunkConfig::default()
        };
        let report = stream_to_shards(&gen, nodes, nodes, edges, seed, cfg, &dir)
            .expect("bench streaming failed");
        assert_eq!(report.edges_written, edges, "wrong streamed edge count at {workers} workers");
        let eps = edges as f64 / report.wall_secs.max(1e-9);
        if workers == 1 {
            stream_seq_eps = eps;
        }
        let speedup = eps / stream_seq_eps.max(1e-9);
        if workers == 4 {
            stream_speedup_at_4 = speedup;
        }
        println!(
            "[bench] streamed workers={workers:2}  {:6.2}s  {:8.2} Medges/s  speedup \
             {speedup:.2}x  (sample {:.2}s, encode {:.2}s, write {:.2}s, writer busy {:.2}s)",
            report.wall_secs,
            eps / 1e6,
            report.sample_secs,
            report.encode_secs,
            report.write_secs,
            report.writer_busy_secs
        );
        streamed.push(Json::obj(vec![
            ("workers", Json::from(workers)),
            ("secs", Json::from(report.wall_secs)),
            ("edges_per_sec", Json::from(eps)),
            ("speedup_vs_sequential", Json::from(speedup)),
            ("sample_secs", Json::from(report.sample_secs)),
            ("encode_secs", Json::from(report.encode_secs)),
            ("write_secs", Json::from(report.write_secs)),
            ("writer_busy_secs", Json::from(report.writer_busy_secs)),
            ("shards", Json::from(report.shards)),
        ]));
        dirs.push(dir);
    }
    let mut names: Vec<String> = std::fs::read_dir(&dirs[0])
        .expect("read bench shard dir")
        .map(|e| e.unwrap().file_name().into_string().unwrap())
        .collect();
    names.sort();
    assert_eq!(
        names.len(),
        std::fs::read_dir(&dirs[1]).unwrap().count(),
        "worker counts produced different shard sets"
    );
    for name in &names {
        let a = std::fs::read(dirs[0].join(name)).unwrap();
        let b = std::fs::read(dirs[1].join(name)).unwrap();
        assert_eq!(a, b, "shard {name} differs between worker counts — determinism broken");
    }
    println!(
        "[bench] streamed output byte-identical across worker counts ({} shards)",
        names.len()
    );
    std::fs::remove_dir_all(&bench_dir).ok();

    let out = Json::obj(vec![
        (
            "scenario",
            Json::obj(vec![
                ("generator", Json::from("kronecker (rmat default theta)")),
                ("nodes", Json::from(nodes)),
                ("edges", Json::from(edges)),
                ("seed", Json::from(seed as u64)),
                ("prefix_levels", Json::from(3u64)),
                ("queue_capacity", Json::from(4u64)),
            ]),
        ),
        ("bit_identical_across_worker_counts", Json::from(true)),
        ("sequential_edges_per_sec", Json::from(seq_eps)),
        ("speedup_at_4_workers", Json::from(speedup_at_4)),
        ("runs", Json::Arr(runs)),
        ("streamed_speedup_at_4_workers", Json::from(stream_speedup_at_4)),
        ("streamed", Json::Arr(streamed)),
    ]);
    std::fs::write("BENCH_parallel.json", format!("{out}\n")).expect("write BENCH_parallel.json");
    println!("[bench] wrote BENCH_parallel.json (speedup@4 = {speedup_at_4:.2}x)");

    // Regression gates, meaningful only where 4 hardware threads exist
    // (laptops/CI — not single-core containers).
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if cores >= 4 {
        assert!(
            speedup_at_4 >= 3.36,
            "speedup_at_4_workers regressed: {speedup_at_4:.2}x < 3.36x (the PR 8 baseline)"
        );
        assert!(
            stream_speedup_at_4 >= 3.0,
            "streamed speedup at 4 workers collapsed: {stream_speedup_at_4:.2}x — the \
             writer is a serial bottleneck again"
        );
    } else {
        println!("[bench] only {cores} hardware threads — skipping the 4-worker speedup gates");
    }
}
