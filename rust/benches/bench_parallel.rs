//! Bench: sequential vs multi-worker chunked generation throughput.
//!
//! Runs the same seeded Kronecker scenario (R-MAT θ, 2²⁰ nodes) through
//! the parallel chunk runner at 1/2/4/8 workers, verifies every run
//! produces the identical edge stream (checksum), and emits
//! `BENCH_parallel.json` with edges/sec per worker count — CI uploads it
//! as an artifact. The single-worker run doubles as the hot-path
//! regression gate for the batched PRNG/alias sampling and the chunk
//! buffer arena: `sequential_edges_per_sec` is tracked at the top level.
//!
//! Run: `cargo bench --bench bench_parallel`
//! Knobs: `SGG_BENCH_EDGES` (default 8_000_000), `SGG_BENCH_NODES`
//! (default 1 << 20).

use sgg::graph::PartiteSpec;
use sgg::structgen::chunked::{generate_chunked, ChunkConfig};
use sgg::structgen::kronecker::KroneckerGen;
use sgg::structgen::theta::ThetaS;
use sgg::util::json::Json;

fn env_u64(key: &str, default: u64) -> u64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let nodes = env_u64("SGG_BENCH_NODES", 1 << 20);
    let edges = env_u64("SGG_BENCH_EDGES", 8_000_000);
    let seed = 0x5a6e;
    let gen = KroneckerGen::new(ThetaS::rmat_default(), PartiteSpec::square(nodes), edges);

    let mut runs: Vec<Json> = Vec::new();
    let mut seq_eps = 0.0f64;
    let mut checksum0: Option<u64> = None;
    let mut speedup_at_4 = 0.0f64;
    for workers in [1usize, 2, 4, 8] {
        let cfg = ChunkConfig { prefix_levels: 3, workers, queue_capacity: 4, ..ChunkConfig::default() };
        // cheap order-sensitive checksum proves runs are bit-identical
        let mut checksum = 0u64;
        let t0 = std::time::Instant::now();
        let total = generate_chunked(&gen, nodes, nodes, edges, seed, cfg, |chunk| {
            for (s, d) in chunk.edges.iter() {
                checksum = checksum
                    .rotate_left(1)
                    .wrapping_add(s.wrapping_mul(0x9e37_79b9).wrapping_add(d));
            }
            Ok(())
        })
        .expect("bench generation failed");
        let secs = t0.elapsed().as_secs_f64();
        assert_eq!(total, edges, "wrong edge count at {workers} workers");
        match checksum0 {
            None => checksum0 = Some(checksum),
            Some(c) => assert_eq!(
                c, checksum,
                "output changed at {workers} workers — determinism broken"
            ),
        }
        let eps = edges as f64 / secs.max(1e-9);
        if workers == 1 {
            seq_eps = eps;
        }
        let speedup = eps / seq_eps.max(1e-9);
        if workers == 4 {
            speedup_at_4 = speedup;
        }
        println!(
            "[bench] workers={workers:2}  {secs:6.2}s  {:8.2} Medges/s  speedup {speedup:.2}x",
            eps / 1e6
        );
        runs.push(Json::obj(vec![
            ("workers", Json::from(workers)),
            ("secs", Json::from(secs)),
            ("edges_per_sec", Json::from(eps)),
            ("speedup_vs_sequential", Json::from(speedup)),
        ]));
    }

    let out = Json::obj(vec![
        (
            "scenario",
            Json::obj(vec![
                ("generator", Json::from("kronecker (rmat default theta)")),
                ("nodes", Json::from(nodes)),
                ("edges", Json::from(edges)),
                ("seed", Json::from(seed as u64)),
                ("prefix_levels", Json::from(3u64)),
                ("queue_capacity", Json::from(4u64)),
            ]),
        ),
        ("bit_identical_across_worker_counts", Json::from(true)),
        ("sequential_edges_per_sec", Json::from(seq_eps)),
        ("speedup_at_4_workers", Json::from(speedup_at_4)),
        ("runs", Json::Arr(runs)),
    ]);
    std::fs::write("BENCH_parallel.json", format!("{out}\n")).expect("write BENCH_parallel.json");
    println!("[bench] wrote BENCH_parallel.json (speedup@4 = {speedup_at_4:.2}x)");
}
