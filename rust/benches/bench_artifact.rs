//! Bench: `.sggm` model-artifact save/load throughput.
//!
//! Fits the default pipeline on a stand-in dataset, then measures
//! `FittedPipeline::save` and `FittedPipeline::load` wall-clock over
//! several repetitions, verifies generate-after-load is bit-identical to
//! generate-after-fit, and emits `BENCH_artifact.json` — CI uploads it
//! as an artifact and a snapshot is tracked at the repo root.
//!
//! Run: `cargo bench --bench bench_artifact`
//! Knobs: `SGG_BENCH_DATASET` (default "ieee-fraud"), `SGG_BENCH_REPS`
//! (default 5).

use sgg::pipeline::{FittedPipeline, Pipeline, Registries};
use sgg::util::json::Json;

fn main() {
    let dataset =
        std::env::var("SGG_BENCH_DATASET").unwrap_or_else(|_| "ieee-fraud".to_string());
    let reps: usize = std::env::var("SGG_BENCH_REPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(5);

    let ds = sgg::datasets::load(&dataset, 1).expect("load dataset");
    let t0 = std::time::Instant::now();
    let fitted = Pipeline::builder().fit(&ds).expect("fit");
    let fit_secs = t0.elapsed().as_secs_f64();

    let path = std::env::temp_dir().join(format!("sgg_bench_artifact_{}.sggm", std::process::id()));
    let regs = Registries::builtin();

    let mut save_secs = 0.0f64;
    let mut load_secs = 0.0f64;
    for _ in 0..reps {
        let t = std::time::Instant::now();
        fitted.save(&path).expect("save");
        save_secs += t.elapsed().as_secs_f64();
        let t = std::time::Instant::now();
        let _loaded = FittedPipeline::load(&path, &regs).expect("load");
        load_secs += t.elapsed().as_secs_f64();
    }
    save_secs /= reps as f64;
    load_secs /= reps as f64;
    let artifact_bytes = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);

    // contract check: load-then-generate == fit-then-generate, bit-exact
    let loaded = FittedPipeline::load(&path, &regs).expect("load");
    let a = fitted.generate(1, 7).expect("generate (fit)");
    let b = loaded.generate(1, 7).expect("generate (load)");
    let identical = a.edges.src == b.edges.src
        && a.edges.dst == b.edges.dst
        && a.edge_features == b.edge_features
        && a.node_features == b.node_features;
    assert!(identical, "artifact round-trip changed the generated output");
    std::fs::remove_file(&path).ok();

    println!(
        "[bench] {dataset}: fit {fit_secs:.2}s, save {:.1}ms, load {:.1}ms, {artifact_bytes} bytes",
        save_secs * 1e3,
        load_secs * 1e3
    );
    let out = Json::obj(vec![
        ("dataset", Json::from(dataset.as_str())),
        ("fit_secs", Json::from(fit_secs)),
        ("save_ms", Json::from(save_secs * 1e3)),
        ("load_ms", Json::from(load_secs * 1e3)),
        ("artifact_bytes", Json::from(artifact_bytes)),
        (
            "artifact_mb_per_sec_save",
            Json::from(artifact_bytes as f64 / 1e6 / save_secs.max(1e-9)),
        ),
        (
            "artifact_mb_per_sec_load",
            Json::from(artifact_bytes as f64 / 1e6 / load_secs.max(1e-9)),
        ),
        ("roundtrip_bit_identical", Json::from(identical)),
        ("reps", Json::from(reps)),
    ]);
    std::fs::write("BENCH_artifact.json", format!("{out}\n")).expect("write BENCH_artifact.json");
    println!("[bench] wrote BENCH_artifact.json");
}
