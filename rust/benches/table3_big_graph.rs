//! Bench: regenerates paper Table 3 (big-graph generation scaling) —
//! chunked structural generation + tabular phase timings per scale.
//!
//! Run: `cargo bench --bench table3_big_graph`

fn main() {
    let t0 = std::time::Instant::now();
    sgg::experiments::table3::run(true).expect("table3");
    println!("\n[bench] table3 end-to-end: {:.2}s", t0.elapsed().as_secs_f64());
}
