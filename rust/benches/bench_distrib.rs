//! Bench: distributed plan → per-host generate → merge.
//!
//! Fits a pipeline, plans an N-host run from the saved `.sggm`
//! artifact, executes every host range into its own directory, then
//! measures `merge_run` (validation + shard assembly + metric fold) and
//! the metric fold alone. Asserts the merged directory's folded degree
//! profile is **bit-identical** to a single-process run of the same
//! artifact and seed, and emits `BENCH_distrib.json` with merge
//! throughput and fold cost.
//!
//! Run: `cargo bench --bench bench_distrib`
//! Knobs: `SGG_BENCH_DATASET` (default travel-insurance),
//! `SGG_BENCH_SCALE` (default 8), `SGG_BENCH_HOSTS` (default 3),
//! `SGG_BENCH_WORKERS` (default 4).

use sgg::metrics::degree::{self, DegreeAccumulator};
use sgg::metrics::stream::profile_shards;
use sgg::pipeline::distrib::{self, HostReport};
use sgg::pipeline::{FittedPipeline, Pipeline, Registries, ShardSink, SizeSpec};
use sgg::structgen::chunked::ChunkConfig;
use sgg::util::json::Json;
use std::path::PathBuf;

fn env_u64(key: &str, default: u64) -> u64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn tmp(name: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("sgg_bench_distrib_{}_{name}", std::process::id()));
    std::fs::remove_dir_all(&p).ok();
    p
}

fn main() {
    let dataset =
        std::env::var("SGG_BENCH_DATASET").unwrap_or_else(|_| "travel-insurance".into());
    let scale = env_u64("SGG_BENCH_SCALE", 8);
    let hosts = env_u64("SGG_BENCH_HOSTS", 3) as usize;
    let workers = env_u64("SGG_BENCH_WORKERS", 4) as usize;
    let regs = Registries::builtin();

    // --- fit + plan from the artifact ---
    let ds = sgg::datasets::load(&dataset, 1).expect("dataset");
    let fitted = Pipeline::builder()
        .structure("kronecker")
        .edge_features("random")
        .aligner("random")
        .fit(&ds)
        .expect("fit");
    let model = std::env::temp_dir().join(format!("sgg_bench_distrib_{}.sggm", std::process::id()));
    fitted.save(&model).expect("save artifact");
    let manifest = distrib::plan_run(&model, hosts, scale, 7, 3, &regs).expect("plan");
    println!(
        "[bench] plan: {} chunks over {hosts} hosts, {} edges at scale {scale}",
        manifest.total_chunks, manifest.edges
    );

    // --- per-host generation ---
    let mut host_dirs = Vec::with_capacity(hosts);
    let mut host_runs: Vec<Json> = Vec::new();
    let t0 = std::time::Instant::now();
    for h in &manifest.hosts {
        let dir = tmp(&format!("h{}", h.host));
        let t = std::time::Instant::now();
        let (report, _) = distrib::run_host_range(
            &model,
            &manifest,
            h.start,
            h.end,
            &dir,
            workers,
            false,
            Default::default(),
            &regs,
        )
        .expect("host range");
        let secs = t.elapsed().as_secs_f64();
        println!(
            "[bench] host {}: chunks {}..{} ({} shards) in {secs:.2}s",
            h.host,
            h.start,
            h.end,
            report.chunks.len()
        );
        host_runs.push(Json::obj(vec![
            ("host", Json::from(h.host)),
            ("chunks", Json::from(h.end - h.start)),
            ("shards", Json::from(report.chunks.len())),
            ("secs", Json::from(secs)),
        ]));
        host_dirs.push(dir);
    }
    let generate_secs = t0.elapsed().as_secs_f64();

    // --- the fold alone: load reports, merge the degree partials ---
    let t0 = std::time::Instant::now();
    let mut acc = DegreeAccumulator::new();
    for dir in &host_dirs {
        let report = HostReport::load(dir).expect("host report");
        if let Some(partial) = &report.profile {
            acc.merge(partial.to_accumulator().expect("partial"));
        }
    }
    let folded = acc.finalize();
    let fold_secs = t0.elapsed().as_secs_f64();
    println!("[bench] fold alone: {} hosts in {fold_secs:.4}s", host_dirs.len());

    // --- merge: validation + assembly + fold ---
    let merged = tmp("merged");
    let t0 = std::time::Instant::now();
    let report = distrib::merge_run(&manifest, &host_dirs, &merged, None).expect("merge");
    let merge_secs = t0.elapsed().as_secs_f64();
    println!(
        "[bench] merge: {} edges / {} bytes in {merge_secs:.2}s ({:.1} Medges/s)",
        report.edges,
        report.bytes,
        report.edges as f64 / merge_secs.max(1e-9) / 1e6
    );
    assert_eq!(
        report.profile_hash,
        degree::profile_hash(&folded),
        "fold diverged from merge"
    );

    // --- identity: single-process run from the same artifact + seed ---
    let single = tmp("single");
    let loaded = FittedPipeline::load(&model, &regs).expect("load artifact");
    let cfg = ChunkConfig {
        prefix_levels: manifest.prefix_levels,
        workers: workers.max(1),
        ..ChunkConfig::default()
    };
    let mut sink = ShardSink::new(&single, cfg).expect("sink");
    let size = SizeSpec::Sized {
        n_src: manifest.n_src,
        n_dst: manifest.n_dst,
        edges: manifest.edges,
    };
    loaded.run(size, cfg, &mut sink, manifest.seed).expect("single run");
    let (single_prof, _) = profile_shards(&single, workers.max(1)).expect("single profile");
    assert_eq!(
        report.profile_hash,
        degree::profile_hash(&single_prof),
        "merged profile diverged from the single-process run"
    );
    println!("[bench] merged profile bit-matches the single-process run ✓");

    let out = Json::obj(vec![
        (
            "scenario",
            Json::obj(vec![
                ("dataset", Json::from(dataset.as_str())),
                ("scale", Json::from(scale)),
                ("hosts", Json::from(hosts)),
                ("workers", Json::from(workers)),
                ("chunks", Json::from(manifest.total_chunks)),
                ("edges", Json::from(manifest.edges)),
            ]),
        ),
        ("generate", Json::obj(vec![("secs", Json::from(generate_secs))])),
        ("host_runs", Json::Arr(host_runs)),
        (
            "merge",
            Json::obj(vec![
                ("secs", Json::from(merge_secs)),
                ("edges_per_sec", Json::from(report.edges as f64 / merge_secs.max(1e-9))),
                ("bytes_per_sec", Json::from(report.bytes as f64 / merge_secs.max(1e-9))),
                ("shards", Json::from(report.shards)),
                ("bytes", Json::from(report.bytes)),
            ]),
        ),
        ("fold", Json::obj(vec![("secs", Json::from(fold_secs))])),
        ("merged_matches_single_process_bit_for_bit", Json::from(true)),
    ]);
    std::fs::write("BENCH_distrib.json", format!("{out}\n")).expect("write BENCH_distrib.json");
    println!("[bench] wrote BENCH_distrib.json");

    std::fs::remove_file(&model).ok();
    std::fs::remove_dir_all(&single).ok();
    std::fs::remove_dir_all(&merged).ok();
    for dir in &host_dirs {
        std::fs::remove_dir_all(dir).ok();
    }
}
