"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py), including
hypothesis sweeps over shapes/values and gradient checks through the
custom VJPs."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.gcn_layer import gcn_layer
from compile.kernels.ref import gcn_layer_ref, resnet_block_ref
from compile.kernels.resnet_block import resnet_block, vmem_estimate

jax.config.update("jax_platform_name", "cpu")


def rand(rng, *shape):
    return rng.standard_normal(shape).astype(np.float32)


class TestResnetBlock:
    def test_matches_ref_basic(self):
        rng = np.random.default_rng(0)
        x, xn = rand(rng, 8, 16), rand(rng, 8, 32)
        w, b = rand(rng, 32, 16), rand(rng, 16)
        got = resnet_block(x, xn, w, b)
        want = resnet_block_ref(x, xn, w, b)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    @settings(max_examples=20, deadline=None)
    @given(
        m=st.sampled_from([1, 2, 4, 8, 16, 64, 256]),
        k=st.sampled_from([1, 3, 16, 64, 128]),
        n=st.sampled_from([1, 2, 16, 64, 128]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_shapes(self, m, k, n, seed):
        rng = np.random.default_rng(seed)
        x, xn = rand(rng, m, n), rand(rng, m, k)
        w, b = rand(rng, k, n), rand(rng, n)
        got = resnet_block(x, xn, w, b)
        want = resnet_block_ref(x, xn, w, b)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_gradients_match_ref(self):
        rng = np.random.default_rng(1)
        x, xn = rand(rng, 16, 8), rand(rng, 16, 24)
        w, b = rand(rng, 24, 8), rand(rng, 8)

        def loss_kernel(x, xn, w, b):
            return jnp.sum(resnet_block(x, xn, w, b) ** 2)

        def loss_ref(x, xn, w, b):
            return jnp.sum(resnet_block_ref(x, xn, w, b) ** 2)

        gk = jax.grad(loss_kernel, argnums=(0, 1, 2, 3))(x, xn, w, b)
        gr = jax.grad(loss_ref, argnums=(0, 1, 2, 3))(x, xn, w, b)
        for a, b_ in zip(gk, gr):
            np.testing.assert_allclose(a, b_, rtol=1e-4, atol=1e-4)

    def test_relu_inactive_region(self):
        # all-negative pre-activation: out == x exactly
        x = np.ones((4, 4), np.float32)
        xn = np.ones((4, 4), np.float32)
        w = -np.ones((4, 4), np.float32)
        b = np.zeros(4, np.float32)
        got = resnet_block(x, xn, w, b)
        np.testing.assert_allclose(got, x)

    def test_vmem_estimate_within_budget(self):
        est = vmem_estimate(256, 256, 256)
        # must fit comfortably in a 16 MB VMEM with double buffering
        assert est["vmem_bytes"] * 2 < 16 * 2**20
        assert 0.0 < est["mxu_tile_utilization"] <= 1.0


class TestGcnLayer:
    def test_matches_ref_basic(self):
        rng = np.random.default_rng(2)
        a = (rng.random((32, 32)) < 0.2).astype(np.float32)
        hw = rand(rng, 32, 16)
        np.testing.assert_allclose(
            gcn_layer(a, hw), gcn_layer_ref(a, hw), rtol=1e-5, atol=1e-5
        )

    @settings(max_examples=12, deadline=None)
    @given(
        n=st.sampled_from([1, 4, 32, 128, 256]),
        h=st.sampled_from([1, 8, 64]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_shapes(self, n, h, seed):
        rng = np.random.default_rng(seed)
        a = rand(rng, n, n)
        hw = rand(rng, n, h)
        np.testing.assert_allclose(
            gcn_layer(a, hw), gcn_layer_ref(a, hw), rtol=1e-4, atol=1e-4
        )

    def test_gradients_match_ref(self):
        rng = np.random.default_rng(3)
        a, hw = rand(rng, 16, 16), rand(rng, 16, 8)

        def lk(a, hw):
            return jnp.sum(jnp.sin(gcn_layer(a, hw)))

        def lr(a, hw):
            return jnp.sum(jnp.sin(gcn_layer_ref(a, hw)))

        gk = jax.grad(lk, argnums=(0, 1))(a, hw)
        gr = jax.grad(lr, argnums=(0, 1))(a, hw)
        for x, y in zip(gk, gr):
            np.testing.assert_allclose(x, y, rtol=1e-4, atol=1e-4)

    def test_zero_adjacency_gives_zero(self):
        a = np.zeros((8, 8), np.float32)
        hw = np.ones((8, 8), np.float32)
        np.testing.assert_allclose(gcn_layer(a, hw), np.zeros((8, 8)))
