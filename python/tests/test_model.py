"""L2 model checks: GAN train step shapes + learning signal, GNN steps
shapes + accuracy improvement on a separable toy problem."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import gnn, model

jax.config.update("jax_platform_name", "cpu")

WIDTH = 32  # small width for test speed (not an artifact bucket)


def flat_adam_state(manifest):
    return [np.zeros(s, np.float32) for _, s in manifest]


class TestGanModel:
    def test_manifest_and_init_agree(self):
        mani = model.gan_manifest(WIDTH)
        params = model.init_gan_params(WIDTH, seed=0)
        assert len(mani) == len(params)
        for (_, shape), p in zip(mani, params):
            assert tuple(shape) == p.shape

    def test_generator_output_range(self):
        params = model.init_gan_params(WIDTH, seed=1)
        g_len = len([n for n, _ in model.gan_manifest(WIDTH) if n.startswith("g_")])
        z = np.random.default_rng(0).standard_normal((model.BATCH, model.Z_DIM)).astype(np.float32)
        fake = model.generator([jnp.asarray(p) for p in params[:g_len]], z)
        assert fake.shape == (model.BATCH, WIDTH)
        assert float(jnp.max(jnp.abs(fake))) <= 1.0

    def test_train_step_improves_discriminator(self):
        mani = model.gan_manifest(WIDTH)
        params = model.init_gan_params(WIDTH, seed=2)
        m = flat_adam_state(mani)
        v = flat_adam_state(mani)
        step = jax.jit(model.make_gan_train_step(WIDTH))
        rng = np.random.default_rng(3)
        real = (rng.standard_normal((model.BATCH, WIDTH)) * 0.3 + 0.5).astype(np.float32)
        d0 = None
        for t in range(8):
            z = rng.standard_normal((model.BATCH, model.Z_DIM)).astype(np.float32)
            out = step(*params, *m, *v, np.float32(t), real, z, np.float32(1e-3))
            k = len(mani)
            params = [np.asarray(x) for x in out[:k]]
            m = [np.asarray(x) for x in out[k:2 * k]]
            v = [np.asarray(x) for x in out[2 * k:3 * k]]
            d_loss = float(out[-2])
            if d0 is None:
                d0 = d_loss
        assert d_loss < d0, f"d_loss {d0} -> {d_loss}"
        assert np.isfinite(d_loss) and np.isfinite(float(out[-1]))

    def test_sample_shapes(self):
        g_len = len([n for n, _ in model.gan_manifest(WIDTH) if n.startswith("g_")])
        params = model.init_gan_params(WIDTH, seed=4)[:g_len]
        sample = jax.jit(model.make_gan_sample(WIDTH))
        z = np.zeros((model.BATCH, model.Z_DIM), np.float32)
        (fake,) = sample(*params, z)
        assert fake.shape == (model.BATCH, WIDTH)


def toy_graph(n=64, classes=2, seed=0):
    """Two-block homophilous graph + separable features."""
    rng = np.random.default_rng(seed)
    labels = (np.arange(n) % classes).astype(int)
    a = np.zeros((n, n), np.float32)
    for i in range(n):
        a[i, i] = 1.0
        for j in range(i + 1, n):
            p = 0.3 if labels[i] == labels[j] else 0.02
            if rng.random() < p:
                a[i, j] = a[j, i] = 1.0
    deg = a.sum(1)
    d_inv = 1.0 / np.sqrt(np.maximum(deg, 1.0))
    a_hat = (a * d_inv[:, None]) * d_inv[None, :]
    x = np.zeros((n, gnn.FEAT), np.float32)
    for i in range(n):
        x[i, labels[i]] = 1.0
        x[i] += rng.standard_normal(gnn.FEAT).astype(np.float32) * 0.3
    y1h = np.zeros((n, gnn.CLASSES), np.float32)
    y1h[np.arange(n), labels] = 1.0
    train = (rng.random(n) < 0.5).astype(np.float32)
    val = 1.0 - train
    return a_hat.astype(np.float32), a, x, y1h, train, val


@pytest.mark.parametrize("kind", ["gcn", "gat"])
def test_node_clf_learns(kind):
    mani = gnn.gcn_manifest() if kind == "gcn" else gnn.gat_manifest()
    params = gnn.init_params(mani, seed=1)
    m, v = flat_adam_state(mani), flat_adam_state(mani)
    a_hat, a_mask, x, y1h, train, val = toy_graph()
    adj = a_hat if kind == "gcn" else a_mask
    step = jax.jit(gnn.make_node_clf_step(kind))
    val_acc = 0.0
    for t in range(40):
        out = step(*params, *m, *v, np.float32(t), adj, x, y1h, train, val, np.float32(0.02))
        k = len(mani)
        params = [np.asarray(o) for o in out[:k]]
        m = [np.asarray(o) for o in out[k:2 * k]]
        v = [np.asarray(o) for o in out[2 * k:3 * k]]
        val_acc = float(out[-1])
    assert val_acc > 0.85, f"{kind} val_acc={val_acc}"


def test_edge_clf_step_runs():
    mani = gnn.edge_clf_manifest()
    params = gnn.init_params(mani, seed=2)
    m, v = flat_adam_state(mani), flat_adam_state(mani)
    n, e = 64, 256
    rng = np.random.default_rng(5)
    a_hat, _, x, _, _, _ = toy_graph(n)
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    ef = rng.standard_normal((e, gnn.EDGE_FEAT)).astype(np.float32)
    labels = (ef[:, 0] > 0).astype(int)
    y1h = np.zeros((e, 2), np.float32)
    y1h[np.arange(e), labels] = 1.0
    train = (np.arange(e) % 2 == 0).astype(np.float32)
    val = 1.0 - train
    step = jax.jit(gnn.make_edge_clf_step())
    acc = 0.0
    for t in range(60):
        out = step(*params, *m, *v, np.float32(t), a_hat, x, src, dst, ef, y1h, train, val,
                   np.float32(0.02))
        k = len(mani)
        params = [np.asarray(o) for o in out[:k]]
        m = [np.asarray(o) for o in out[k:2 * k]]
        v = [np.asarray(o) for o in out[2 * k:3 * k]]
        acc = float(out[-1])
    # edge label depends only on edge feature -> easily separable
    assert acc > 0.85, f"edge val_acc={acc}"
