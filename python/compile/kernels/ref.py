"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness).

Every Pallas kernel in this package must match its reference here to
float32 tolerance; ``python/tests/test_kernels.py`` enforces it with
hypothesis sweeps over shapes.
"""

import jax.numpy as jnp


def resnet_block_ref(x, xn, w, b):
    """Reference for the fused ResNet block tail (paper §3.3):

        out = x + relu(xn @ w + b)

    ``xn`` is the batch-normalized input (BN runs in the surrounding jnp
    graph because its batch statistics are a global reduction); the fused
    kernel covers the FLOPs-dominant matmul + bias + ReLU + residual.
    Dropout is identity at artifact time (see DESIGN.md).
    """
    return x + jnp.maximum(xn @ w + b, 0.0)


def gcn_layer_ref(a_hat, hw):
    """Reference for the fused GCN propagation (paper §8.1 models):

        out = relu(a_hat @ hw)

    ``a_hat`` is the normalized dense adjacency and ``hw = h @ w`` the
    pre-projected features (the projection is cheap; the N×N propagation
    is the hot spot).
    """
    return jnp.maximum(a_hat @ hw, 0.0)
