"""L1 Pallas kernel: fused dense GCN propagation.

The GNN experiments (paper §8.1/§8.4) run 2-layer GCN/GAT models; on a
dense padded adjacency the hot spot is the N×N propagation
``relu(Â @ (H W))``. The H @ W projection is cheap (N×F×H) and stays in
jnp; this kernel tiles the propagation:

    out[i, j] = relu( Σ_k a_hat[i, k] · hw[k, j] )

Grid over (row tiles × col tiles) with a K-loop over Â row slabs —
identical scheduling story to ``resnet_block`` (see that module for the
TPU mapping rationale). interpret=True on this CPU image.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .resnet_block import _pick_tile


def _kernel(a_ref, hw_ref, o_ref, *, n_k_tiles: int, bk: int):
    acc = jnp.zeros(o_ref.shape, dtype=jnp.float32)
    for k in range(n_k_tiles):
        ak = a_ref[:, k * bk:(k + 1) * bk]
        hk = hw_ref[k * bk:(k + 1) * bk, :]
        acc = acc + jnp.dot(ak, hk, preferred_element_type=jnp.float32)
    o_ref[...] = jnp.maximum(acc, 0.0)


def _forward(a_hat, hw):
    n, k_in = a_hat.shape
    k2, h = hw.shape
    assert k_in == k2, (a_hat.shape, hw.shape)
    bm = _pick_tile(n, 256)
    bn = _pick_tile(h, 128)
    bk = _pick_tile(k_in, 256)
    n_k_tiles = k_in // bk
    kernel = functools.partial(_kernel, n_k_tiles=n_k_tiles, bk=bk)
    return pl.pallas_call(
        kernel,
        grid=(n // bm, h // bn),
        in_specs=[
            pl.BlockSpec((bm, k_in), lambda i, j: (i, 0)),
            pl.BlockSpec((k_in, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, h), jnp.float32),
        interpret=True,
    )(a_hat, hw)


@jax.custom_vjp
def gcn_layer(a_hat, hw):
    """Fused ``relu(a_hat @ hw)`` (see module docstring)."""
    return _forward(a_hat, hw)


def _fwd(a_hat, hw):
    out = _forward(a_hat, hw)
    return out, (a_hat, hw, out)


def _bwd(res, g):
    a_hat, hw, out = res
    g_pre = jnp.where(out > 0.0, g, 0.0)
    da = g_pre @ hw.T
    dhw = a_hat.T @ g_pre
    return da, dhw


gcn_layer.defvjp(_fwd, _bwd)
