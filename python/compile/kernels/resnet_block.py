"""L1 Pallas kernel: fused ResNet-block tail for the tabular GAN.

The paper's feature GAN stacks ``ResNetBlock(x) = x + Dropout(ReLU(FC(
BatchNorm(x))))`` (§3.3). BatchNorm's batch statistics are a global
reduction, so it stays in the surrounding jnp graph; this kernel fuses the
FLOPs-dominant remainder — matmul, bias, ReLU, residual add — into one
VMEM-resident pass:

    out[i, j] = x[i, j] + relu( Σ_k xn[i, k] · w[k, j] + b[j] )

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles (batch ×
out-features); each program instance keeps an (BM × BN) accumulator in
VMEM and loops over K-tiles of ``xn`` and ``w``, feeding MXU-shaped
(128-aligned when the problem allows) matmul tiles. On this CPU image the
kernel runs under ``interpret=True`` (Mosaic custom-calls cannot execute
on the CPU PJRT plugin); correctness is enforced against ``ref.py``.

Backward: ``jax.custom_vjp`` with a hand-derived jnp backward — pallas
forward + analytic VJP keeps the train-step artifact differentiable.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_tile(n: int, cap: int) -> int:
    """Largest divisor of n that is ≤ cap (tiles must divide the dims)."""
    for t in range(min(n, cap), 0, -1):
        if n % t == 0:
            return t
    return n


def _kernel(x_ref, xn_ref, w_ref, b_ref, o_ref, *, n_k_tiles: int, bk: int):
    """One (BM × BN) output tile: K-loop accumulate, then bias+relu+res."""
    acc = jnp.zeros(o_ref.shape, dtype=jnp.float32)
    for k in range(n_k_tiles):
        xk = xn_ref[:, k * bk:(k + 1) * bk]
        wk = w_ref[k * bk:(k + 1) * bk, :]
        acc = acc + jnp.dot(xk, wk, preferred_element_type=jnp.float32)
    o_ref[...] = x_ref[...] + jnp.maximum(acc + b_ref[...], 0.0)


def _forward(x, xn, w, b):
    m, d = x.shape
    k_in, d_out = w.shape
    assert xn.shape == (m, k_in) and d == d_out, (x.shape, xn.shape, w.shape)
    bm = _pick_tile(m, 128)
    bn = _pick_tile(d_out, 128)
    bk = _pick_tile(k_in, 128)
    n_k_tiles = k_in // bk
    grid = (m // bm, d_out // bn)
    kernel = functools.partial(_kernel, n_k_tiles=n_k_tiles, bk=bk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),      # x (residual)
            pl.BlockSpec((bm, k_in), lambda i, j: (i, 0)),    # xn rows
            pl.BlockSpec((k_in, bn), lambda i, j: (0, j)),    # w cols
            pl.BlockSpec((bn,), lambda i, j: (j,)),           # bias slice
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, d_out), jnp.float32),
        interpret=True,
    )(x, xn, w, b)


@jax.custom_vjp
def resnet_block(x, xn, w, b):
    """Fused ``x + relu(xn @ w + b)`` (see module docstring)."""
    return _forward(x, xn, w, b)


def _fwd(x, xn, w, b):
    out = _forward(x, xn, w, b)
    return out, (x, xn, w, b, out)


def _bwd(res, g):
    x, xn, w, b, out = res
    # relu mask from the forward: active where out - x > 0
    mask = (out - x) > 0.0
    g_pre = jnp.where(mask, g, 0.0)
    dx = g
    dxn = g_pre @ w.T
    dw = xn.T @ g_pre
    db = jnp.sum(g_pre, axis=0)
    return dx, dxn, dw, db


resnet_block.defvjp(_fwd, _bwd)


def vmem_estimate(m: int, k: int, n: int, bm: int = 128, bn: int = 128,
                  bk: int = 128) -> dict:
    """Static VMEM/MXU estimate for DESIGN.md §Perf (interpret=True gives
    no hardware counters; structure is what we can reason about).

    Returns bytes held in VMEM per program instance and the MXU tile
    utilization (fraction of a 128×128 systolic pass that is useful work).
    """
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    bytes_vmem = 4 * (bm * bn       # accumulator + x tile (reused)
                      + bm * bk     # xn K-tile
                      + bk * bn     # w K-tile
                      + bn)         # bias
    mxu_util = (bm / 128) * (bn / 128)
    return {"vmem_bytes": bytes_vmem, "mxu_tile_utilization": min(mxu_util, 1.0)}
