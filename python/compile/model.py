"""L2 JAX model: the CTGAN-style tabular feature GAN (paper §3.3).

Generator and discriminator are stacks of the paper's ResNet blocks
``x + Dropout(ReLU(FC(BatchNorm(x))))`` whose fused tail is the L1 Pallas
kernel (``kernels.resnet_block``); BatchNorm statistics are computed in
the surrounding graph. Both networks train jointly with the
non-saturating GAN objective (paper eq. 13/14) under Adam.

Parameters cross the Rust boundary as a *flat ordered list* of f32
arrays; the manifest (name, shape) list is emitted next to each artifact
so the Rust runtime can initialize, pack and unpack them without any
Python at run time.
"""

import numpy as np
import jax
import jax.numpy as jnp

from .kernels.resnet_block import resnet_block

Z_DIM = 64
BATCH = 256
N_BLOCKS = 2
ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8
BN_EPS = 1e-5


# --------------------------------------------------------------------------
# parameter manifest
# --------------------------------------------------------------------------

def gan_manifest(width: int, hidden: int | None = None):
    """Ordered (name, shape) list for the GAN parameter flat-pack."""
    h = hidden or max(width, 64)
    spec = []

    def net(prefix, d_in, d_out):
        spec.append((f"{prefix}_fc_in_w", (d_in, h)))
        spec.append((f"{prefix}_fc_in_b", (h,)))
        for i in range(N_BLOCKS):
            spec.append((f"{prefix}_blk{i}_bn_scale", (h,)))
            spec.append((f"{prefix}_blk{i}_bn_bias", (h,)))
            spec.append((f"{prefix}_blk{i}_fc_w", (h, h)))
            spec.append((f"{prefix}_blk{i}_fc_b", (h,)))
        spec.append((f"{prefix}_fc_out_w", (h, d_out)))
        spec.append((f"{prefix}_fc_out_b", (d_out,)))

    net("g", Z_DIM, width)
    net("d", width, 1)
    return spec


def init_gan_params(width: int, seed: int = 0):
    """He-initialized flat parameter list in manifest order (numpy, so the
    values can be serialized for the Rust side)."""
    rng = np.random.default_rng(seed)
    params = []
    for name, shape in gan_manifest(width):
        if name.endswith("_w"):
            fan_in = shape[0]
            params.append(
                rng.normal(0.0, np.sqrt(2.0 / fan_in), size=shape).astype(np.float32)
            )
        elif name.endswith("bn_scale"):
            params.append(np.ones(shape, dtype=np.float32))
        else:
            params.append(np.zeros(shape, dtype=np.float32))
    return params


# --------------------------------------------------------------------------
# networks
# --------------------------------------------------------------------------

def _batchnorm(x, scale, bias):
    mu = jnp.mean(x, axis=0, keepdims=True)
    var = jnp.var(x, axis=0, keepdims=True)
    xn = (x - mu) / jnp.sqrt(var + BN_EPS)
    return xn * scale + bias


def _stack(params, offset, x):
    """Shared G/D trunk: FC in → N ResNet blocks (Pallas tail) → FC out.

    Returns (output, next_offset)."""
    i = offset
    w, b = params[i], params[i + 1]
    i += 2
    h = jnp.maximum(x @ w + b, 0.0)
    for _ in range(N_BLOCKS):
        bn_s, bn_b, fc_w, fc_b = params[i], params[i + 1], params[i + 2], params[i + 3]
        i += 4
        hn = _batchnorm(h, bn_s, bn_b)
        h = resnet_block(h, hn, fc_w, fc_b)
    w, b = params[i], params[i + 1]
    i += 2
    return h @ w + b, i


def generator(params, z):
    """G: z → tanh(trunk(z)) ∈ [−1, 1]^width (α slots and soft one-hots)."""
    out, _ = _stack(params, 0, z)
    return jnp.tanh(out)


def discriminator(params, g_len, x):
    """D: x → logit."""
    out, _ = _stack(params, g_len, x)
    return out[:, 0]


def _g_len(width: int) -> int:
    return len([n for n, _ in gan_manifest(width) if n.startswith("g_")])


# --------------------------------------------------------------------------
# training step (AOT entry point)
# --------------------------------------------------------------------------

def gan_losses(params, g_len, real, z):
    fake = generator(params[:g_len], z)
    logit_real = discriminator(params, g_len, real)
    logit_fake = discriminator(params, g_len, fake)
    d_loss = jnp.mean(jax.nn.softplus(-logit_real)) + jnp.mean(
        jax.nn.softplus(logit_fake)
    )
    g_loss = jnp.mean(jax.nn.softplus(-logit_fake))
    return d_loss, g_loss


def make_gan_train_step(width: int):
    """Build train_step(params…, m…, v…, t, real, z, lr) → (params…, m…,
    v…, d_loss, g_loss) with flat-list params (manifest order)."""
    g_len = _g_len(width)
    n_params = len(gan_manifest(width))

    def train_step(*args):
        params = list(args[:n_params])
        m = list(args[n_params:2 * n_params])
        v = list(args[2 * n_params:3 * n_params])
        t, real, z, lr = args[3 * n_params:]

        def d_obj(d_part):
            full = params[:g_len] + list(d_part)
            return gan_losses(full, g_len, real, z)[0]

        def g_obj(g_part):
            full = list(g_part) + params[g_len:]
            return gan_losses(full, g_len, real, z)[1]

        d_loss, d_grads = jax.value_and_grad(d_obj)(tuple(params[g_len:]))
        g_loss, g_grads = jax.value_and_grad(g_obj)(tuple(params[:g_len]))
        grads = list(g_grads) + list(d_grads)

        t1 = t + 1.0
        new_p, new_m, new_v = [], [], []
        for p, mi, vi, g in zip(params, m, v, grads):
            mi = ADAM_B1 * mi + (1.0 - ADAM_B1) * g
            vi = ADAM_B2 * vi + (1.0 - ADAM_B2) * g * g
            mhat = mi / (1.0 - ADAM_B1 ** t1)
            vhat = vi / (1.0 - ADAM_B2 ** t1)
            new_p.append(p - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS))
            new_m.append(mi)
            new_v.append(vi)
        return tuple(new_p + new_m + new_v + [d_loss, g_loss])

    return train_step


def make_gan_sample(width: int):
    """Build sample(g_params…, z) → fake batch."""
    g_len = _g_len(width)

    def sample(*args):
        g_params = list(args[:g_len])
        z = args[g_len]
        return (generator(g_params, z),)

    return sample


def gan_example_args(width: int):
    """ShapeDtypeStructs for lowering the train step."""
    f32 = jnp.float32
    manifest = gan_manifest(width)
    p = [jax.ShapeDtypeStruct(s, f32) for _, s in manifest]
    scalars = [
        jax.ShapeDtypeStruct((), f32),            # t
        jax.ShapeDtypeStruct((BATCH, width), f32),  # real
        jax.ShapeDtypeStruct((BATCH, Z_DIM), f32),  # z
        jax.ShapeDtypeStruct((), f32),            # lr
    ]
    return p + p + p + scalars


def gan_sample_example_args(width: int):
    f32 = jnp.float32
    manifest = gan_manifest(width)
    g = [jax.ShapeDtypeStruct(s, f32) for n, s in manifest if n.startswith("g_")]
    return g + [jax.ShapeDtypeStruct((BATCH, Z_DIM), f32)]
