"""AOT lowering: JAX (L2, calling L1 Pallas kernels) → HLO *text*
artifacts + parameter manifests + initial parameter packs.

HLO text — not serialized HloModuleProto — is the interchange format:
jax ≥ 0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md).

Run once via ``make artifacts``; the Rust binary is self-contained
afterwards. Usage: ``python -m compile.aot --out-dir ../artifacts``.
"""

import argparse
import json
import os

import numpy as np
import jax
from jax._src.lib import xla_client as xc

from . import gnn, model

# GAN width buckets: the Rust encoder pads its encoded row width into the
# smallest bucket that fits (see rust/src/runtime/gan_exec.rs).
GAN_WIDTHS = (128, 256)
# Node-classification padding buckets.
NODE_NS = (1024, 4096)
# Edge-classifier bucket: (padded nodes, padded edges).
EDGE_CLF = (2048, 32768)


def to_hlo_text(fn, example_args) -> str:
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def write_artifact(out_dir, name, fn, example_args, manifest=None, init=None):
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    text = to_hlo_text(fn, example_args)
    with open(path, "w") as f:
        f.write(text)
    print(f"  {name}.hlo.txt ({len(text) / 1e6:.1f} MB)")
    if manifest is not None:
        meta = {
            "name": name,
            "params": [{"name": n, "shape": list(s)} for n, s in manifest],
        }
        with open(os.path.join(out_dir, f"{name}.manifest.json"), "w") as f:
            json.dump(meta, f, indent=1)
    if init is not None:
        flat = np.concatenate([p.reshape(-1) for p in init]).astype("<f4")
        flat.tofile(os.path.join(out_dir, f"{name}.init.bin"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default="", help="comma-separated artifact name filter")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    only = [s for s in args.only.split(",") if s]

    def wanted(name):
        return not only or any(o in name for o in only)

    print("lowering artifacts:")
    for w in GAN_WIDTHS:
        mani = model.gan_manifest(w)
        if wanted(f"gan_train_w{w}"):
            write_artifact(
                args.out_dir,
                f"gan_train_w{w}",
                model.make_gan_train_step(w),
                model.gan_example_args(w),
                manifest=mani,
                init=model.init_gan_params(w, seed=0),
            )
        if wanted(f"gan_sample_w{w}"):
            write_artifact(
                args.out_dir,
                f"gan_sample_w{w}",
                model.make_gan_sample(w),
                model.gan_sample_example_args(w),
            )
    for n in NODE_NS:
        for kind in ("gcn", "gat"):
            name = f"{kind}_full_n{n}"
            if not wanted(name):
                continue
            mani = gnn.gcn_manifest() if kind == "gcn" else gnn.gat_manifest()
            write_artifact(
                args.out_dir,
                name,
                gnn.make_node_clf_step(kind),
                gnn.node_clf_example_args(kind, n),
                manifest=mani,
                init=gnn.init_params(mani, seed=0),
            )
    n, e = EDGE_CLF
    if wanted("edge_clf"):
        mani = gnn.edge_clf_manifest()
        write_artifact(
            args.out_dir,
            f"edge_clf_n{n}_e{e}",
            gnn.make_edge_clf_step(),
            gnn.edge_clf_example_args(n, e),
            manifest=mani,
            init=gnn.init_params(mani, seed=0),
        )
    # constants the Rust runtime needs
    with open(os.path.join(args.out_dir, "artifacts.json"), "w") as f:
        json.dump(
            {
                "gan_widths": list(GAN_WIDTHS),
                "gan_batch": model.BATCH,
                "gan_z_dim": model.Z_DIM,
                "node_ns": list(NODE_NS),
                "node_feat": gnn.FEAT,
                "node_classes": gnn.CLASSES,
                "edge_clf": {"n": n, "e": e, "edge_feat": gnn.EDGE_FEAT},
            },
            f,
            indent=1,
        )
    print("done")


if __name__ == "__main__":
    main()
