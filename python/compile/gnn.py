"""L2 JAX models for the downstream GNN experiments (paper §8.1/§8.4/§8.5):
2-layer GCN and GAT over dense padded adjacencies, plus an edge
classifier (GCN embeddings + MLP head). The N×N propagation runs through
the L1 Pallas kernel ``kernels.gcn_layer``.

Artifacts:
* ``gcn_full_{N}`` / ``gat_full_{N}`` — full-batch node-classification
  train step (Table 7 pretrain/finetune, Figure 4, Table 4 timing).
* ``edge_clf_{N}`` — edge-classification train step (IEEE-Fraud task).

All shapes are static; graphs are padded into the bucket by the Rust
side (rows beyond the real node count are isolated zero-feature nodes
excluded by the masks).
"""

import numpy as np
import jax
import jax.numpy as jnp

from .kernels.gcn_layer import gcn_layer

HIDDEN = 64
CLASSES = 8
FEAT = 32
EDGE_FEAT = 16
ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


# --------------------------------------------------------------------------
# manifests / init
# --------------------------------------------------------------------------

def gcn_manifest():
    return [
        ("w1", (FEAT, HIDDEN)),
        ("w2", (HIDDEN, CLASSES)),
    ]


def gat_manifest():
    return [
        ("w1", (FEAT, HIDDEN)),
        ("a_l1", (HIDDEN,)),
        ("a_r1", (HIDDEN,)),
        ("w2", (HIDDEN, CLASSES)),
        ("a_l2", (CLASSES,)),
        ("a_r2", (CLASSES,)),
    ]


def edge_clf_manifest():
    return [
        ("w1", (FEAT, HIDDEN)),
        ("w2", (HIDDEN, HIDDEN)),
        ("head_w1", (2 * HIDDEN + EDGE_FEAT, HIDDEN)),
        ("head_b1", (HIDDEN,)),
        ("head_w2", (HIDDEN, 2)),
        ("head_b2", (2,)),
    ]


def init_params(manifest, seed: int = 0):
    rng = np.random.default_rng(seed)
    out = []
    for name, shape in manifest:
        if name.endswith("_b1") or name.endswith("_b2"):
            out.append(np.zeros(shape, dtype=np.float32))
        elif len(shape) == 1:
            out.append(rng.normal(0.0, 0.1, size=shape).astype(np.float32))
        else:
            fan_in = shape[0]
            out.append(
                rng.normal(0.0, np.sqrt(2.0 / fan_in), size=shape).astype(np.float32)
            )
    return out


# --------------------------------------------------------------------------
# forward passes
# --------------------------------------------------------------------------

def gcn_forward(params, a_hat, x):
    w1, w2 = params
    h1 = gcn_layer(a_hat, x @ w1)          # fused relu(Â X W1)
    logits = a_hat @ (h1 @ w2)             # linear output layer
    return logits


def _gat_layer(a_mask, h, w, a_l, a_r, relu: bool):
    """Single-head dense GAT layer. ``a_mask`` is the 0/1 adjacency with
    self-loops; attention logits are masked to the edge set."""
    hw = h @ w
    el = hw @ a_l                          # (N,)
    er = hw @ a_r
    e = jax.nn.leaky_relu(el[:, None] + er[None, :], 0.2)
    e = jnp.where(a_mask > 0.0, e, -1e9)
    alpha = jax.nn.softmax(e, axis=1)
    out = alpha @ hw
    return jnp.maximum(out, 0.0) if relu else out


def gat_forward(params, a_mask, x):
    w1, a_l1, a_r1, w2, a_l2, a_r2 = params
    h1 = _gat_layer(a_mask, x, w1, a_l1, a_r1, relu=True)
    return _gat_layer(a_mask, h1, w2, a_l2, a_r2, relu=False)


def masked_ce(logits, labels_1h, mask):
    logp = jax.nn.log_softmax(logits, axis=1)
    per = -jnp.sum(labels_1h * logp, axis=1)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(per * mask) / denom


def masked_acc(logits, labels_1h, mask):
    pred = jnp.argmax(logits, axis=1)
    truth = jnp.argmax(labels_1h, axis=1)
    hit = (pred == truth).astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(hit * mask) / denom


# --------------------------------------------------------------------------
# train steps (AOT entry points)
# --------------------------------------------------------------------------

def _adam(params, m, v, grads, t, lr):
    t1 = t + 1.0
    new_p, new_m, new_v = [], [], []
    for p, mi, vi, g in zip(params, m, v, grads):
        mi = ADAM_B1 * mi + (1.0 - ADAM_B1) * g
        vi = ADAM_B2 * vi + (1.0 - ADAM_B2) * g * g
        mhat = mi / (1.0 - ADAM_B1 ** t1)
        vhat = vi / (1.0 - ADAM_B2 ** t1)
        new_p.append(p - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS))
        new_m.append(mi)
        new_v.append(vi)
    return new_p, new_m, new_v


def make_node_clf_step(kind: str):
    """kind ∈ {gcn, gat}: train_step(params…, m…, v…, t, a, x, y1h,
    train_mask, val_mask, lr) → (params…, m…, v…, loss, train_acc,
    val_acc)."""
    manifest = gcn_manifest() if kind == "gcn" else gat_manifest()
    fwd = gcn_forward if kind == "gcn" else gat_forward
    k = len(manifest)

    def step(*args):
        params = list(args[:k])
        m = list(args[k:2 * k])
        v = list(args[2 * k:3 * k])
        t, a, x, y1h, train_mask, val_mask, lr = args[3 * k:]

        def obj(ps):
            return masked_ce(fwd(list(ps), a, x), y1h, train_mask)

        loss, grads = jax.value_and_grad(obj)(tuple(params))
        new_p, new_m, new_v = _adam(params, m, v, list(grads), t, lr)
        logits = fwd(new_p, a, x)
        return tuple(
            new_p + new_m + new_v
            + [loss, masked_acc(logits, y1h, train_mask), masked_acc(logits, y1h, val_mask)]
        )

    return step


def node_clf_example_args(kind: str, n: int):
    f32 = jnp.float32
    manifest = gcn_manifest() if kind == "gcn" else gat_manifest()
    p = [jax.ShapeDtypeStruct(s, f32) for _, s in manifest]
    rest = [
        jax.ShapeDtypeStruct((), f32),            # t
        jax.ShapeDtypeStruct((n, n), f32),        # a (normalized or mask)
        jax.ShapeDtypeStruct((n, FEAT), f32),     # x
        jax.ShapeDtypeStruct((n, CLASSES), f32),  # y one-hot
        jax.ShapeDtypeStruct((n,), f32),          # train mask
        jax.ShapeDtypeStruct((n,), f32),          # val mask
        jax.ShapeDtypeStruct((), f32),            # lr
    ]
    return p + p + p + rest


def edge_clf_forward(params, a_hat, x, src_idx, dst_idx, edge_feat):
    w1, w2, hw1, hb1, hw2, hb2 = params
    h1 = gcn_layer(a_hat, x @ w1)
    h2 = gcn_layer(a_hat, h1 @ w2)
    hs = jnp.take(h2, src_idx, axis=0)
    hd = jnp.take(h2, dst_idx, axis=0)
    z = jnp.concatenate([hs, hd, edge_feat], axis=1)
    z = jnp.maximum(z @ hw1 + hb1, 0.0)
    return z @ hw2 + hb2


def make_edge_clf_step():
    """train_step(params…, m…, v…, t, a, x, src, dst, efeat, y1h,
    train_mask, val_mask, lr) → (params…, m…, v…, loss, train_acc,
    val_acc)."""
    k = len(edge_clf_manifest())

    def step(*args):
        params = list(args[:k])
        m = list(args[k:2 * k])
        v = list(args[2 * k:3 * k])
        t, a, x, src, dst, ef, y1h, train_mask, val_mask, lr = args[3 * k:]

        def obj(ps):
            return masked_ce(edge_clf_forward(list(ps), a, x, src, dst, ef), y1h, train_mask)

        loss, grads = jax.value_and_grad(obj)(tuple(params))
        new_p, new_m, new_v = _adam(params, m, v, list(grads), t, lr)
        logits = edge_clf_forward(new_p, a, x, src, dst, ef)
        return tuple(
            new_p + new_m + new_v
            + [loss, masked_acc(logits, y1h, train_mask), masked_acc(logits, y1h, val_mask)]
        )

    return step


def edge_clf_example_args(n: int, e: int):
    f32 = jnp.float32
    i32 = jnp.int32
    p = [jax.ShapeDtypeStruct(s, f32) for _, s in edge_clf_manifest()]
    rest = [
        jax.ShapeDtypeStruct((), f32),
        jax.ShapeDtypeStruct((n, n), f32),
        jax.ShapeDtypeStruct((n, FEAT), f32),
        jax.ShapeDtypeStruct((e,), i32),
        jax.ShapeDtypeStruct((e,), i32),
        jax.ShapeDtypeStruct((e, EDGE_FEAT), f32),
        jax.ShapeDtypeStruct((e, 2), f32),
        jax.ShapeDtypeStruct((e,), f32),
        jax.ShapeDtypeStruct((e,), f32),
        jax.ShapeDtypeStruct((), f32),
    ]
    return p + p + p + rest
